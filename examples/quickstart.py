"""Quickstart: the Dalorex engine in 30 lines.

Runs BFS + SSSP + PageRank on an RMAT graph over 16 emulated tiles, checks
against sequential oracles, and prints the engine telemetry that the paper's
figures are built from.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges


def main():
    n, src, dst, val = rmat_edges(scale=10, edge_factor=10, seed=0)
    g = CSRGraph.from_edges(n, src, dst, val)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges")

    pg = alg.prepare(g, T=16)  # 16 tiles, low-order placement, equal edges
    cfg = EngineConfig()
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))

    res = alg.bfs(pg, root, cfg)
    assert (res.values == ref.bfs_ref(g, root)).all()
    print(f"bfs    ok: rounds={int(res.stats.rounds)} "
          f"msgs={int(res.stats.msgs_update)} "
          f"spills={int(res.stats.spills_update)} "
          f"drops={int(res.stats.drops)}")

    res = alg.sssp(pg, root, cfg)
    expect = ref.sssp_ref(g, root)
    finite = np.isfinite(expect)
    np.testing.assert_allclose(res.values[finite], expect[finite],
                               rtol=1e-5)
    print(f"sssp   ok: rounds={int(res.stats.rounds)} "
          f"edges relaxed={int(res.stats.edges_scanned)}")

    res = alg.pagerank(pg, iters=10, cfg=cfg)
    np.testing.assert_allclose(res.values, ref.pagerank_ref(g, iters=10),
                               rtol=2e-3, atol=1e-7)
    print(f"pagerank ok: epochs={res.epochs} "
          f"rounds={int(res.stats.rounds)}")


if __name__ == "__main__":
    main()
