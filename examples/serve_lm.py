"""Batched serving: prefill a batch of prompts into the ring KV cache, then
greedy-decode continuations — the serve-side end-to-end driver.

  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b \\
      --batch 4 --prompt-len 32 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size, jnp.int32)

    cache = tfm.init_cache(cfg, B, tfm.cache_slots(cfg, total))
    t0 = time.perf_counter()
    _, cache = tfm.prefill(params, cfg, cache, {"tokens": prompts})
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B}x{P} tokens in {t_prefill*1e3:.0f} ms "
          f"({B*P/t_prefill:.0f} tok/s), cache pos={int(cache.pos)}")

    step = jax.jit(lambda p, c, t: tfm.serve_step(p, cfg, c, t))
    tok = prompts[:, -1:]
    out = []
    t0 = time.perf_counter()
    for _ in range(G):
        nxt, cache = step(params, cache, tok)
        tok = nxt[:, None]
        out.append(nxt)
    jax.block_until_ready(tok)
    t_gen = time.perf_counter() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decode: {B}x{G} tokens in {t_gen*1e3:.0f} ms "
          f"({B*G/t_gen:.0f} tok/s)")
    print("sample continuation ids:", gen[0, :16].tolist())
    assert bool((gen >= 0).all())
    print("OK")


if __name__ == "__main__":
    main()
