"""Graph analytics end to end: all five paper workloads, both placements,
both sync modes, on a LiveJournal-like synthetic (heavy-tailed RMAT) —
the paper's Section V evaluation in miniature — plus a NoC-topology
comparison (ideal crossbar vs mesh vs torus vs ruche) showing the
per-link telemetry of the pluggable fabric (paper Fig. 9), and the two
task-graph workloads (k-core peeling, 2-hop triangle counting) that the
generic task-program executor opens beyond the fixed T1/T2/T3 pipeline.

  PYTHONPATH=src python examples/graph_analytics.py [--scale 12]
      [--preset rmat-hier] [--backend pallas] [--noc hier]
      [--ndies-y 2 --ndies-x 2] [--placement low_order_dielocal]
      [--queries 32]

``--queries N`` appends the serving section: N BFS/SSSP sources batched
through the engine as query lanes (src/repro/serve/), with a queries/sec
line per app.

``--preset`` pulls scale/tiles/edge-factor/backend/noc/ndies/placement
from ``repro.configs.dalorex_graph.PRESETS``; explicit flags override it.
``--backend pallas`` runs every engine call on the tile-grid kernels
(bit-identical results; interpret mode on CPU).  ``--noc hier`` runs the
workload table on the multi-die fabric (``--ndies-y x --ndies-x`` dies);
a ``*_dielocal`` ``--placement`` keeps graph partitions die-resident.
The NoC ablation table always includes the hier rows with their
die-crossing fraction.
"""
import argparse
import functools

import numpy as np

from repro.configs.dalorex_graph import PRESETS
from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig as _EngineConfig
from repro.core.graph import CSRGraph, rmat_edges


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None)
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--tiles", type=int, default=None)
    ap.add_argument("--backend", choices=("xla", "pallas"), default=None)
    ap.add_argument("--noc", default=None,
                    choices=("ideal", "mesh", "torus", "ruche", "hier"))
    ap.add_argument("--ndies-y", type=int, default=None)
    ap.add_argument("--ndies-x", type=int, default=None)
    ap.add_argument("--placement", default=None,
                    choices=("low_order", "high_order",
                             "low_order_dielocal", "high_order_dielocal"))
    ap.add_argument("--edge-space", choices=("vmem", "hbm"), default=None,
                    help="memory space of the per-tile edge shard "
                         "(repro.mem): hbm streams it through "
                         "double-buffered segment DMA, bit-identical "
                         "values (triangles stays on its pinned vmem "
                         "shard)")
    ap.add_argument("--queries", type=int, default=0,
                    help="also serve N batched multi-source BFS/SSSP "
                         "queries (the repro.serve query lanes) and print "
                         "a queries/sec line")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="run the BFS row with the flight recorder on "
                         "(repro.trace), write the Chrome/Perfetto trace "
                         "JSON to PATH and print the utilization summary "
                         "(results stay bit-identical; see DESIGN.md "
                         "'Tracing & observability')")
    args = ap.parse_args()
    wl = PRESETS[args.preset] if args.preset else None
    scale = args.scale if args.scale is not None else \
        (wl.scale if wl else 11)
    tiles = args.tiles if args.tiles is not None else \
        (wl.tiles if wl else 16)
    backend = args.backend if args.backend is not None else \
        (wl.backend if wl else "xla")
    noc = args.noc if args.noc is not None else (wl.noc if wl else "ideal")
    ndies = (args.ndies_y if args.ndies_y is not None else
             (wl.ndies[0] if wl else 1),
             args.ndies_x if args.ndies_x is not None else
             (wl.ndies[1] if wl else 1))
    placement = args.placement if args.placement is not None else \
        (wl.placement if wl else "low_order")
    dies = ndies if placement.endswith("_dielocal") else None
    ef = wl.edge_factor if wl else 10
    edge_space = args.edge_space if args.edge_space is not None else \
        (wl.edge_space if wl else "vmem")
    hbm_window = wl.hbm_window if wl else 0
    cfg_kw = dict(backend=backend, noc=noc, ndies_y=ndies[0],
                  ndies_x=ndies[1], edge_space=edge_space,
                  hbm_window=hbm_window,
                  adapt=wl.adapt if wl else False,
                  adapt_every=wl.adapt_every if wl else 4,
                  adapt_budget=wl.adapt_budget if wl else 64)
    # size the queues from the engine's worst-case inflow when the grid
    # outgrows the defaults (the T=64 hier presets), like
    # benchmarks.common.engine_cfg; smaller grids keep the defaults
    rangeq, burst = _EngineConfig(**cfg_kw).min_caps(tiles)
    cfg_kw["cap_rangeq"] = max(_EngineConfig.cap_rangeq,
                               1 << (rangeq - 1).bit_length())
    cfg_kw["cap_updq"] = max(_EngineConfig.cap_updq,
                             1 << (burst - 1).bit_length())
    EngineConfig = functools.partial(_EngineConfig, **cfg_kw)

    n, src, dst, val = rmat_edges(scale, edge_factor=ef, seed=1)
    g = CSRGraph.from_edges(n, src, dst, val)
    gs = alg.symmetrize(g)
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    print(f"V={g.num_vertices} E={g.num_edges} tiles={tiles} "
          f"backend={backend} noc={noc} ndies={ndies[0]}x{ndies[1]} "
          f"placement={placement}")
    print(f"{'app':10s} {'mode':6s} {'rounds':>7s} {'msgs':>9s} "
          f"{'spills':>7s} {'edges':>9s}  check")

    for mode in ("async", "bsp"):
        c = EngineConfig(mode=mode)
        pg = alg.prepare(g, tiles, scheme=placement, dies=dies)
        pgs = alg.prepare(gs, tiles, scheme=placement, dies=dies)
        for app in ("bfs", "sssp", "wcc", "pagerank", "spmv"):
            if app == "bfs":
                res = alg.bfs(pg, root, c)
                ok = (res.values == ref.bfs_ref(g, root)).all()
            elif app == "sssp":
                res = alg.sssp(pg, root, c)
                e = ref.sssp_ref(g, root)
                f = np.isfinite(e)
                ok = np.allclose(res.values[f], e[f], rtol=1e-5)
            elif app == "wcc":
                res = alg.wcc(pgs, c)
                ok = (res.values == ref.wcc_ref(gs)).all()
            elif app == "pagerank":  # keeps its barrier, as in the paper
                prc = EngineConfig(mode="bsp")
                if prc.adapt:
                    # adaptive preset: migrate at epoch boundaries from
                    # the recorder's busy cycles (repro.place); the
                    # relabeling contract keeps the reference check intact
                    import dataclasses as _dc

                    from repro.place import adaptive_pagerank
                    prc = _dc.replace(prc, trace=True, trace_rounds=4096)
                    res, _, plans = adaptive_pagerank(g, pg, iters=8,
                                                      cfg=prc)
                    assert plans, "adapt preset applied no migration plan"
                else:
                    res = alg.pagerank(pg, iters=8, cfg=prc)
                ok = np.allclose(res.values, ref.pagerank_ref(g, iters=8),
                                 rtol=2e-3, atol=1e-7)
            else:
                x = np.random.default_rng(0).normal(
                    size=g.num_vertices).astype(np.float32)
                res = alg.spmv(pg, x, c)
                ok = np.allclose(res.values, ref.spmv_ref(g, x), rtol=2e-4,
                                 atol=1e-4)
            s = res.stats
            print(f"{app:10s} {mode:6s} {int(s.rounds):7d} "
                  f"{int(s.msgs_range + s.msgs_update):9d} "
                  f"{int(s.spills_range + s.spills_update):7d} "
                  f"{int(s.edges_scanned):9d}  "
                  f"{'OK' if ok else 'FAIL'}")
            assert ok, app
            assert int(s.drops) == 0

    # Flight recorder (--trace): the async BFS again with the per-round
    # trace on — results stay bit-identical (asserted), and the run's
    # timeline lands in a Chrome/Perfetto JSON (ui.perfetto.dev) plus the
    # utilization / work-imbalance / queue-depth table of repro.trace.
    if args.trace:
        import dataclasses
        from repro.trace import (format_summary, reconcile_cycles,
                                 summarize, write_perfetto)
        pg_t = alg.prepare(g, tiles, scheme=placement, dies=dies)
        cfg0 = EngineConfig(mode="async")
        cfg_t = dataclasses.replace(cfg0, trace=True, trace_rounds=4096)
        base = alg.bfs(pg_t, root, cfg0)
        res = alg.bfs(pg_t, root, cfg_t)
        assert (res.values == base.values).all() \
            and float(res.stats.cycles) == float(base.stats.cycles), \
            "the flight recorder must not perturb the run"
        rec = reconcile_cycles(res.trace,
                               float(np.asarray(res.stats.cycles)))
        doc = write_perfetto(res.trace, args.trace,
                             meta={"app": "bfs", "noc": noc,
                                   "placement": placement,
                                   "tiles": tiles, "scale": scale})
        print(f"\nflight recorder: bfs traced "
              f"{int(res.stats.rounds)} rounds -> {args.trace} "
              f"({len(doc['traceEvents'])} events), cycle reconcile "
              f"exact={rec['exact']}")
        print(format_summary(summarize(res.trace)))

    # NoC topology ablation: same BFS, five fabrics (the hier rows run the
    # multi-die composition with and without die-local placement — the
    # die-crossing fraction is the new hierarchy column).  Uncapped links
    # expose each wiring's hotspot structure; drops stay 0 by construction.
    from repro.noc import grid_shape
    from repro.perf import die_crossing_frac
    rows_, cols_ = grid_shape(tiles)
    hnd = ndies if ndies != (1, 1) else (2, 2)
    print(f"\n{'noc':22s} {'rounds':>7s} {'spills':>7s} "
          f"{'max_link_occ':>13s} {'avg_hops':>9s} {'die_frac':>9s}")
    pg = alg.prepare(g, tiles)
    expect = ref.bfs_ref(g, root)
    fabrics = [("ideal", pg), ("mesh", pg), ("torus", pg), ("ruche", pg)]
    if rows_ % hnd[0] or cols_ % hnd[1]:
        # a single-die "hier" row would just be the mesh again — say so
        # instead of printing misleadingly-labeled rows
        print(f"(hier rows skipped: {rows_}x{cols_} grid not divisible "
              f"into {hnd[0]}x{hnd[1]} dies)")
    else:
        pg_dl = alg.prepare(g, tiles, scheme="low_order_dielocal", dies=hnd)
        fabrics += [("hier", pg), ("hier+dielocal", pg_dl)]
    for name, pgx in fabrics:
        noc_kind = name.split("+")[0]
        res = alg.bfs(pgx, root, EngineConfig(
            noc=noc_kind, ndies_y=hnd[0] if noc_kind == "hier" else 1,
            ndies_x=hnd[1] if noc_kind == "hier" else 1))
        s = res.stats
        hist = np.asarray(s.hop_histogram)
        avg = (hist * np.arange(len(hist))).sum() / max(hist.sum(), 1)
        die_frac = die_crossing_frac(s)
        assert (res.values == expect).all() and int(s.drops) == 0
        print(f"{name:22s} {int(s.rounds):7d} "
              f"{int(s.spills_range + s.spills_update):7d} "
              f"{int(s.max_link_occupancy):13d} {avg:9.2f} {die_frac:9.2f}")

    # Query serving: N BFS/SSSP sources batched through the engine as
    # vmapped query lanes (src/repro/serve/) — one resident graph, shared
    # rounds, per-query results identical to solo runs.  The queries/sec
    # line is the serving headline of benchmarks/fig12_serving.py.
    if args.queries > 0:
        from repro.serve import Frontend
        deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
        rng = np.random.default_rng(0)
        srcs = rng.choice(np.flatnonzero(deg > 0), size=args.queries)
        width = min(args.queries, 16)
        print(f"\nserving {args.queries} queries, {width} lanes "
              f"(static batches, burst arrivals)")
        print(f"{'app':10s} {'rounds':>7s} {'seq_rounds':>10s} "
              f"{'qps':>12s} {'pJ/query':>10s} {'lat_p95':>8s}  check")
        for app, rf in (("bfs", ref.bfs_ref), ("sssp", ref.sssp_ref)):
            fe = Frontend(pg, app=app, cfg=EngineConfig(), width=width)
            rep = fe.serve(srcs)
            ok = rep.drops == 0
            for rec in rep.records:
                e = rf(g, rec.source)
                f = np.isfinite(e)
                ok = ok and bool(np.allclose(rec.values[f], e[f],
                                             rtol=1e-5)) \
                    and bool(np.isinf(rec.values[~f]).all())
            if args.queries > 1:  # batching must amortize rounds
                ok = ok and rep.total_rounds < rep.seq_rounds
            print(f"{app:10s} {rep.total_rounds:7d} {rep.seq_rounds:10d} "
                  f"{rep.qps:12.1f} {rep.j_per_query * 1e12:10.1f} "
                  f"{rep.latency_cycles(95):8.0f}  "
                  f"{'OK' if ok else 'FAIL'}")
            assert ok, app

    # Task-graph workloads on the generic executor: a different T3 fold
    # (k-core peel) and a 4-channel chain (2-hop triangle counting).
    print(f"\n{'app':10s} {'rounds':>7s} {'msgs':>9s} {'result':>10s}  check")
    for k in (2, 3):
        res = alg.kcore(pgs, k, EngineConfig())
        ok = (res.values == ref.kcore_ref(gs, k)).all()
        s = res.stats
        print(f"{'kcore' + str(k):10s} {int(s.rounds):7d} "
              f"{int(np.asarray(s.msgs).sum()):9d} "
              f"{int(res.values.sum()):10d}  {'OK' if ok else 'FAIL'}")
        assert ok and int(s.drops) == 0
    pgt = alg.prepare_triangles(gs, tiles)
    # triangles pins its edge shard to vmem (the closing fold
    # binary-searches the resident adjacency) — honor the pin here
    # instead of asking resolve_edge_space for the impossible.
    res = alg.triangles(pgt, EngineConfig(edge_space="vmem"))
    ok = (res.values == ref.triangles_ref(gs, key=pgt.place)).all()
    s = res.stats
    print(f"{'triangles':10s} {int(s.rounds):7d} "
          f"{int(np.asarray(s.msgs).sum()):9d} "
          f"{int(res.values.sum()):10d}  {'OK' if ok else 'FAIL'}")
    assert ok and int(s.drops) == 0
    print("per-channel msgs (range/wedge/range2/close):",
          np.asarray(s.msgs).tolist())


if __name__ == "__main__":
    main()
