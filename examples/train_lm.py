"""End-to-end LM training driver: train a ~100M-param model for a few
hundred steps on synthetic Markov data, with checkpoints + auto-resume.

Any assigned architecture is selectable; widths are scaled to ~100M params
for the CPU run (the FULL configs are exercised by the dry-run):

  PYTHONPATH=src python examples/train_lm.py --arch granite-3-2b \\
      --steps 300 --batch 8 --seq 256

Kill it mid-run and re-run the same command: it resumes from the newest
valid checkpoint at the exact step (seekable data pipeline).
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import TrainConfig, train


def scale_to_100m(cfg):
    """Shrink a full config to ~100M params, keeping its family intact."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-100m",
        num_layers=min(cfg.num_layers, 12 if cfg.family != "hybrid"
                       else 2 * cfg.attn_every),
        d_model=768,
        num_heads=min(cfg.num_heads, 12) if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_heads else 0,
        head_dim=64 if cfg.num_heads else 0,
        d_ff=2304 if not cfg.num_experts else 768,
        vocab_size=16384,
        num_experts=min(cfg.num_experts, 8),
        experts_per_tok=min(cfg.experts_per_tok, 2),
        moe_capacity_factor=2.0,
        sliding_window=min(cfg.sliding_window, 1024) or 0,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = scale_to_100m(get_config(args.arch))
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"active~{cfg.active_param_count()/1e6:.1f}M")
    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq,
        ckpt_every=50, ckpt_dir=args.ckpt_dir, data="markov",
        microbatches=args.microbatches, log_every=10,
        opt=OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                      compress_grads=args.compress_grads))
    _, _, hist = train(cfg, tc)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(started {hist[0]['loss']:.4f}); "
          f"stragglers flagged: {sum(h['straggler'] for h in hist)}")


if __name__ == "__main__":
    main()
