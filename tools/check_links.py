"""Markdown link-check for the repo's front-door docs.

Scans the given markdown files for references to repo files and fails if
any are dead — so README/DESIGN/benchmarks docs cannot silently rot when
code moves (the failure mode this repo's docs layer was born with).

Two reference forms are checked, both resolved against the repo root:

* markdown links ``[text](target)`` with a relative target (http(s),
  mailto and pure #anchor targets are skipped);
* inline-code path tokens (backticked) that start with a known top-level
  code directory — ``src/``, ``benchmarks/``, ``examples/``, ``tests/``,
  ``tools/``, ``.github/`` — e.g. ``src/repro/noc/network.py``.

A token passes if it exists as a file or directory; module-attribute
spellings like ``benchmarks/fig8_noc.run_hier`` pass when the module file
(``benchmarks/fig8_noc.py``) exists.  Tokens containing glob characters
are skipped.

  python tools/check_links.py README.md DESIGN.md benchmarks/README.md
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CODE_ROOTS = ("src/", "benchmarks/", "examples/", "tests/", "tools/",
              ".github/")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_TOKEN = re.compile(r"`([^`\s]+)`")


def _exists(target: str) -> bool:
    """True if ``target`` names a repo file/dir, allowing a trailing
    ``.attr`` module-member suffix on a ``.py`` module."""
    path = os.path.join(REPO, target.rstrip("/"))
    if os.path.exists(path):
        return True
    # benchmarks/fig8_noc.run_hier -> benchmarks/fig8_noc.py
    head, _, _ = target.rpartition(".")
    return bool(head) and os.path.exists(os.path.join(REPO, head + ".py"))


def check_file(md_path: str) -> list[str]:
    """Return human-readable problems for one markdown file."""
    with open(os.path.join(REPO, md_path)) as f:
        text = f.read()
    problems = []
    seen = set()

    def check(target: str, kind: str):
        if target in seen or any(ch in target for ch in "*?$"):
            return
        seen.add(target)
        if not _exists(target):
            problems.append(f"{md_path}: dead {kind} reference {target!r}")

    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#")[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        check(target, "link")
    for m in CODE_TOKEN.finditer(text):
        token = m.group(1)
        if token.startswith(CODE_ROOTS):
            check(token, "path")
    return problems


def main(files: list[str]) -> int:
    problems = []
    for md in files:
        if not os.path.exists(os.path.join(REPO, md)):
            problems.append(f"{md}: file not found")
            continue
        problems.extend(check_file(md))
    for p in problems:
        print(p)
    if not problems:
        print(f"link-check OK: {len(files)} file(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or
                  ["README.md", "DESIGN.md", "benchmarks/README.md"]))
