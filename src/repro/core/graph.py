"""Graph representation and partitioning for the Dalorex engine.

A graph arrives as host-side CSR (numpy). Partitioning applies a placement
permutation to vertex IDs (``low_order`` = Dalorex scatter, ``high_order`` =
Tesseract-like chunks, ``degree_interleave`` = degree-aware round-robin,
each with a ``*_dielocal`` variant that pins contiguous partitions to the
dies of the hier NoC), rebuilds the CSR in placed order, and splits the four dataset arrays
(``ptr``-derived start/degree, ``edge_dst``, ``edge_val``) in equal chunks
across T shards, exactly as Section III-A prescribes.  The rebuild is pure
numpy segment arithmetic (repeat/cumsum gathers, no per-vertex Python
loop), so scale-14+ graphs partition in fractions of a second rather than
minutes.

Three edge-partition modes; the first two reproduce the Fig. 5
"Data-Local" ablation rung:

* ``equal_edges``     — Dalorex: each tile owns E/T *adjacent* edges,
  decoupled from vertex ownership (ranges may cross tiles; T1 splits them).
* ``vertex_aligned``  — Tesseract-like: a tile owns the edges of its own
  vertices; per-tile edge counts are skewed, so chunks are padded to the max
  (the imbalance the paper's placement removes).
* ``die_aligned``     — the hierarchical composition of the two: the
  Dalorex equal-chunk scatter applied *within* each run of same-die tiles,
  padded at run boundaries so a die's edges never drift into another die's
  chunks.  With die-resident vertices (a ``*_dielocal`` placement) every
  range message then stays on-die by construction, and only
  partition-crossing update edges ride the DIE links.  One die degenerates
  to ``equal_edges`` exactly.  Selected automatically for ``*_dielocal``
  schemes when ``equal_edges`` was requested.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.distribution import DistSpec, placement, padded_len


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR; vertices 0..V-1; ptr has V+1 entries."""

    ptr: np.ndarray  # (V+1,) int64
    dst: np.ndarray  # (E,) int64
    val: np.ndarray  # (E,) float32

    @property
    def num_vertices(self) -> int:
        return len(self.ptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.dst)

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   val: np.ndarray | None = None, dedup: bool = True) -> "CSRGraph":
        if val is None:
            val = np.ones(len(src), np.float32)
        if dedup and len(src):
            key = src.astype(np.int64) * n + dst.astype(np.int64)
            _, idx = np.unique(key, return_index=True)
            src, dst, val = src[idx], dst[idx], val[idx]
        order = np.lexsort((dst, src))
        src, dst, val = src[order], dst[order], val[order]
        counts = np.bincount(src, minlength=n)
        ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return CSRGraph(ptr, dst.astype(np.int64), val.astype(np.float32))


@dataclasses.dataclass
class PartitionedGraph:
    """Device-ready shards; every array has a leading T axis.

    ``ptr_start[t, v]`` is the *global* placed edge index of local vertex v's
    first out-edge; ``deg`` its out-degree. ``edge_dst`` holds *placed* dst
    vertex IDs (-1 padding); ``edge_val`` the weights.
    """

    T: int
    vdist: DistSpec  # placed-vertex space
    edist: DistSpec  # placed-edge space
    ptr_start: jnp.ndarray  # (T, v_chunk) int32
    deg: jnp.ndarray  # (T, v_chunk) int32
    edge_dst: jnp.ndarray  # (T, e_chunk) int32
    edge_val: jnp.ndarray  # (T, e_chunk) float32
    place: np.ndarray  # (V_orig,) original -> placed
    inv: np.ndarray  # (V_pad,) placed -> original (-1 pad)
    num_vertices: int  # original V
    num_edges: int  # original E
    edge_mode: str = "equal_edges"  # how edges were partitioned
    sorted_adj: bool = False  # per-vertex segments sorted by placed dst

    @property
    def v_chunk(self) -> int:
        return self.vdist.chunk

    @property
    def e_chunk(self) -> int:
        return self.edist.chunk


def partition_graph(g: CSRGraph, T: int, scheme: str = "low_order",
                    edge_mode: str = "equal_edges",
                    dies: tuple[int, int] | None = None,
                    tile_die: np.ndarray | None = None) -> PartitionedGraph:
    """``dies=(ndies_y, ndies_x)`` builds the tile -> die map for the
    ``*_dielocal`` placement schemes from the near-square grid the NoC
    uses by default; pass an explicit ``tile_die`` for custom grids."""
    V = g.num_vertices
    deg = (g.ptr[1:] - g.ptr[:-1]
           if scheme.startswith("degree_interleave") else None)
    if tile_die is None and dies is not None:
        from repro.noc.topology import tile_die_map
        tile_die = tile_die_map(T, 0, *dies)
    if scheme.endswith("_dielocal") and edge_mode == "equal_edges":
        # die-resident partitions need die-resident edges, or range
        # messages chase drifted edge chunks across dies (module docstring)
        edge_mode = "die_aligned"
    place, inv = placement(V, T, scheme, deg=deg, tile_die=tile_die)
    return build_partition(g, T, place, inv, edge_mode, tile_die=tile_die)


def build_partition(g: CSRGraph, T: int, place: np.ndarray, inv: np.ndarray,
                    edge_mode: str = "equal_edges",
                    tile_die: np.ndarray | None = None) -> PartitionedGraph:
    """Materialize the shards for an explicit ``(place, inv)`` pair.

    This is the realization half of :func:`partition_graph` (which derives
    the pair from a named scheme first): given any placement permutation —
    a scheme's, or one produced by composing a scheme with a migration
    plan (:mod:`repro.place`) — rebuild the placed CSR and deal the edge
    arrays.  Two calls with the same ``(place, inv, edge_mode, tile_die)``
    produce bitwise-identical shards, which is what makes a migration a
    pure relabeling: the migrated partition is indistinguishable from
    having *started* with the composed placement.
    """
    V, E = g.num_vertices, g.num_edges
    v_pad = len(inv)
    vdist = DistSpec(v_pad, T)

    # Rebuild CSR in placed order: vertex at placed slot p is original inv[p].
    deg_placed = np.zeros(v_pad, np.int64)
    orig_ok = inv >= 0
    deg_placed[orig_ok] = (g.ptr[1:] - g.ptr[:-1])[inv[orig_ok]]

    # Both modes gather the edge arrays with numpy segment ops (repeat +
    # cumsum) instead of a per-vertex Python loop: placed slot p's edges
    # come from g.ptr[inv[p]] + (0..deg) and land at ptr_start[p] + (0..deg).
    # Timing note: the old O(V) host loop took minutes on scale-14+ RMATs
    # (~16k vertices/chunk x T); the segment gather partitions a scale-16
    # graph (65k vertices, 650k edges) in well under a second.
    ok_p = np.nonzero(orig_ok)[0]          # placed slots with a real vertex
    o = inv[ok_p]                          # their original ids
    d = deg_placed[ok_p]
    within = np.arange(int(d.sum()), dtype=np.int64) \
        - np.repeat(np.cumsum(d) - d, d)   # 0..deg-1 inside each segment
    src_idx = np.repeat(g.ptr[o], d) + within

    if edge_mode == "equal_edges":
        new_ptr = np.concatenate([[0], np.cumsum(deg_placed)])
        e_pad = padded_len(max(E, 1), T)
        edist = DistSpec(e_pad, T)
        edge_dst = np.full(e_pad, -1, np.int64)
        edge_val = np.zeros(e_pad, np.float32)
        dst_idx = np.repeat(new_ptr[ok_p], d) + within
        edge_dst[dst_idx] = place[g.dst[src_idx]]
        edge_val[dst_idx] = g.val[src_idx]
        ptr_start = new_ptr[:-1]
    elif edge_mode == "die_aligned":
        # Equal-chunk scatter per run of consecutive same-die tiles: run r
        # (tiles t0..t1) owns edge chunks t0..t1, its vertices' edges laid
        # contiguously from chunk t0 with the padding at the run's tail —
        # so chunk t always belongs to tile t's die.  One die = one run =
        # exactly the equal_edges layout (modulo global tail padding).
        if tile_die is None:
            raise ValueError("die_aligned edge mode needs dies=/tile_die=")
        v_chunk = v_pad // T
        td = np.asarray(tile_die, np.int64)
        deg_t = deg_placed.reshape(T, v_chunk).sum(1)
        run_id = np.concatenate([[0], np.cumsum(td[1:] != td[:-1])])
        run_len = np.bincount(run_id)
        run_edges = np.bincount(run_id, weights=deg_t).astype(np.int64)
        e_chunk = int(max(np.ceil(run_edges / run_len).max(), 1))
        e_pad = e_chunk * T
        edist = DistSpec(e_pad, T)
        edge_dst = np.full(e_pad, -1, np.int64)
        edge_val = np.zeros(e_pad, np.float32)
        # exclusive edge prefix per placed vertex, restarted at run starts
        cum = np.cumsum(deg_placed) - deg_placed
        _, run_first_tile = np.unique(run_id, return_index=True)
        vert_run = run_id[np.arange(v_pad) // v_chunk]
        base = run_first_tile[vert_run]
        ptr_start = base * e_chunk + (cum - cum[base * v_chunk])
        dst_idx = np.repeat(ptr_start[ok_p], d) + within
        edge_dst[dst_idx] = place[g.dst[src_idx]]
        edge_val[dst_idx] = g.val[src_idx]
    elif edge_mode == "vertex_aligned":
        # Each tile owns its vertices' edges; pad every tile to the max count.
        v_chunk = v_pad // T
        degs2 = deg_placed.reshape(T, v_chunk)
        per_tile = degs2.sum(1)
        e_chunk = int(padded_len(max(int(per_tile.max()), 1), 1))
        e_pad = e_chunk * T
        edist = DistSpec(e_pad, T)
        edge_dst = np.full(e_pad, -1, np.int64)
        edge_val = np.zeros(e_pad, np.float32)
        excl = np.cumsum(degs2, axis=1) - degs2  # per-tile exclusive prefix
        ptr_start = (np.arange(T, dtype=np.int64)[:, None] * e_chunk
                     + excl).reshape(-1)
        dst_idx = np.repeat(ptr_start[ok_p], d) + within
        edge_dst[dst_idx] = place[g.dst[src_idx]]
        edge_val[dst_idx] = g.val[src_idx]
    else:
        raise ValueError(f"unknown edge_mode: {edge_mode}")

    v_chunk = v_pad // T
    e_chunk = edist.chunk
    return PartitionedGraph(
        T=T, vdist=vdist, edist=edist,
        ptr_start=jnp.asarray(ptr_start.reshape(T, v_chunk), jnp.int32),
        deg=jnp.asarray(deg_placed.reshape(T, v_chunk), jnp.int32),
        edge_dst=jnp.asarray(edge_dst.reshape(T, e_chunk), jnp.int32),
        edge_val=jnp.asarray(edge_val.reshape(T, e_chunk), jnp.float32),
        place=place, inv=inv, num_vertices=V, num_edges=E,
        edge_mode=edge_mode,
    )


def rmat_edges(scale: int, edge_factor: int = 10, a: float = 0.57, b: float = 0.19,
               c: float = 0.19, seed: int = 0, weights: str = "uniform",
               ) -> tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """R-MAT generator (Kronecker) as used for the paper's synthetic datasets
    (Graph500 parameters a=.57 b=.19 c=.19 d=.05, ~edge_factor edges/vertex)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = (r1 > a + b).astype(np.int64)
        # conditional probabilities per quadrant
        p_dst = np.where(src_bit == 0, b / (a + b), (1 - (a + b + c)) / (1 - (a + b)))
        dst_bit = (r2 < p_dst).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    if weights == "uniform":
        val = rng.uniform(1.0, 10.0, m).astype(np.float32)
    else:
        val = np.ones(m, np.float32)
    return n, src, dst, val
