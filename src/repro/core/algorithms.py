"""Host drivers for the paper workloads on the Dalorex engine.

Each driver: (1) initializes per-shard value/acc/frontier state in *placed*
space, (2) runs its :class:`repro.core.program.Program` on the generic
engine (barrierless or BSP) over a comm backend, and (3) maps results back
to original vertex IDs.

The five seed workloads (BFS, SSSP, PageRank, WCC, SpMV) compile to the
classic 3-task program; :func:`kcore` runs the peel program (threshold
fold); :func:`triangles` runs the 4-channel 2-hop chain over a
vertex-aligned, sorted partition (:func:`prepare_triangles`).

Two execution paths share all engine code:

* ``comm=LocalComm(T)`` — T emulated tiles on one device (tests/benchmarks).
* ``comm=AxisComm(axis, T)`` via :func:`spmd_engine_call` — real shard_map
  SPMD over a device mesh (the production / dry-run path).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import AxisComm, LocalComm, shard_map_compat
from repro.core.engine import (BFS, PAGERANK, SPMV, SSSP, WCC, AlgSpec,
                               EngineConfig, EngineState, GraphShard, INF,
                               Stats, init_state, run_engine, zero_stats)
from repro.core.graph import CSRGraph, PartitionedGraph, partition_graph
from repro.core.program import (TRIANGLES, as_program, kcore_program,
                                sized_cfg)
from repro.trace.buffer import zero_trace


# --------------------------------------------------------------------------
# State initialization in placed space.
# --------------------------------------------------------------------------

def real_mask(pg: PartitionedGraph) -> np.ndarray:
    """(T, v_chunk) bool — slots that hold a real (non-padding) vertex."""
    return (pg.inv >= 0).reshape(pg.T, pg.v_chunk)


def init_min_state(pg: PartitionedGraph, roots: list[int]):
    """value=+inf except roots (=0); frontier = roots."""
    value = np.full((pg.T, pg.v_chunk), np.float32(np.finfo(np.float32).max))
    frontier = np.zeros((pg.T, pg.v_chunk), bool)
    for r in roots:
        p = int(pg.place[r])
        t, l = p // pg.v_chunk, p % pg.v_chunk
        value[t, l] = 0.0
        frontier[t, l] = True
    return jnp.asarray(value), jnp.asarray(frontier)


def init_wcc_state(pg: PartitionedGraph):
    """Label = original vertex id; every real vertex starts in the frontier."""
    inv = pg.inv.reshape(pg.T, pg.v_chunk)
    value = np.where(inv >= 0, inv, np.float32(np.finfo(np.float32).max))
    frontier = inv >= 0
    return jnp.asarray(value, jnp.float32), jnp.asarray(frontier)


def init_add_state(pg: PartitionedGraph, x: np.ndarray):
    """value = x scattered to placed slots; frontier = real vertices with
    out-edges (vertices with deg 0 emit nothing)."""
    flat = np.zeros(pg.T * pg.v_chunk, np.float32)
    flat[pg.place] = x.astype(np.float32)
    value = flat.reshape(pg.T, pg.v_chunk)
    deg = np.asarray(pg.deg)
    frontier = real_mask(pg) & (deg > 0)
    return jnp.asarray(value), jnp.asarray(frontier)


def init_kcore_state(pg: PartitionedGraph, k: int):
    """value = remaining degree; acc = removed flag (1 = out of the core);
    the initially-dead vertices (deg < k, and padding) seed the frontier so
    their decrements propagate."""
    real = real_mask(pg)
    deg = np.asarray(pg.deg)
    value = np.where(real, deg, 0).astype(np.float32)
    dead0 = real & (deg < k)
    acc = np.where(real & ~dead0, 0.0, 1.0).astype(np.float32)
    return jnp.asarray(value), jnp.asarray(dead0), jnp.asarray(acc)


def to_original(pg: PartitionedGraph, arr) -> np.ndarray:
    """(T, v_chunk) placed-space array -> (V,) original order."""
    flat = np.asarray(arr).reshape(-1)
    return flat[pg.place]


# --------------------------------------------------------------------------
# Engine invocation: local emulation and SPMD shard_map.
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("prog", "cfg", "T", "e_chunk", "v_chunk"))
def _local_call(prog, cfg: EngineConfig, T: int, e_chunk: int,
                v_chunk: int, shard: GraphShard, value, frontier, acc):
    comm = LocalComm(T)
    st = init_state(comm, cfg, v_chunk, value, frontier, prog, acc)
    st, stats, trace = run_engine(comm, cfg, prog, shard, st, e_chunk,
                                  v_chunk)
    return st.value, st.acc, stats, trace


def local_engine_call(pg: PartitionedGraph, alg, cfg: EngineConfig,
                      value, frontier, acc=None):
    prog = as_program(alg)
    shard = GraphShard(pg.ptr_start, pg.deg, pg.edge_dst, pg.edge_val)
    if acc is None:
        acc = jnp.zeros_like(value)
    return _local_call(prog, cfg, pg.T, pg.e_chunk, pg.v_chunk, shard,
                       value, frontier, acc)


def spmd_engine_call(pg: PartitionedGraph, alg, cfg: EngineConfig,
                     value, frontier, mesh, axis: str = "x", acc=None):
    """Run the engine as true SPMD under shard_map over ``axis`` of ``mesh``.

    Arrays keep the (T, chunk) layout; the leading axis is sharded so each
    device owns one tile row.  Inside, blocks are squeezed to per-device
    shards and the identical engine code runs with ``AxisComm``.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    T = pg.T
    prog = as_program(alg)
    comm = AxisComm(axis, T)
    spec2 = P(axis, None)
    if acc is None:
        acc = jnp.zeros_like(value)

    def body(ptr_start, deg, edge_dst, edge_val, value, frontier, acc):
        shard = GraphShard(ptr_start[0], deg[0], edge_dst[0], edge_val[0])
        st = init_state(comm, cfg, pg.v_chunk, value[0], frontier[0],
                        prog, acc[0])
        st, stats, trace = run_engine(comm, cfg, prog, shard, st,
                                      pg.e_chunk, pg.v_chunk)
        return st.value[None], st.acc[None], stats, trace

    # the recorder's ring holds only global (replicated) series, so its
    # out_spec is P() everywhere, exactly like Stats (None when trace off)
    trace_spec = jax.tree.map(lambda _: P(), zero_trace(cfg, T, prog)) \
        if cfg.trace else None
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec2,) * 7,
        out_specs=(spec2, spec2, jax.tree.map(lambda _: P(), Stats.zero()),
                   trace_spec))
    args = [jax.device_put(a, NamedSharding(mesh, spec2)) for a in
            (pg.ptr_start, pg.deg, pg.edge_dst, pg.edge_val, value,
             frontier, acc)]
    return jax.jit(fn)(*args)


# --------------------------------------------------------------------------
# Workload drivers.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Result:
    values: np.ndarray  # (V,) in original vertex order
    stats: Stats
    epochs: int = 1
    trace: object = None  # TraceBuf when cfg.trace, else None


def _call(pg, alg, cfg, value, frontier, mesh=None, axis="x", acc=None):
    if mesh is None:
        return local_engine_call(pg, alg, cfg, value, frontier, acc)
    return spmd_engine_call(pg, alg, cfg, value, frontier, mesh, axis, acc)


def bfs(pg: PartitionedGraph, root: int, cfg: EngineConfig = EngineConfig(),
        mesh=None) -> Result:
    value, frontier = init_min_state(pg, [root])
    v, _, stats, trace = _call(pg, BFS, cfg, value, frontier, mesh)
    out = to_original(pg, v).astype(np.float64)
    out[out >= np.float32(np.finfo(np.float32).max)] = np.inf
    return Result(out, stats, trace=trace)


def sssp(pg: PartitionedGraph, root: int, cfg: EngineConfig = EngineConfig(),
         mesh=None) -> Result:
    value, frontier = init_min_state(pg, [root])
    v, _, stats, trace = _call(pg, SSSP, cfg, value, frontier, mesh)
    out = to_original(pg, v).astype(np.float64)
    out[out >= np.float32(np.finfo(np.float32).max)] = np.inf
    return Result(out, stats, trace=trace)


def wcc(pg: PartitionedGraph, cfg: EngineConfig = EngineConfig(),
        mesh=None) -> Result:
    """Label propagation to the min original id (graph must be symmetric)."""
    value, frontier = init_wcc_state(pg)
    v, _, stats, trace = _call(pg, WCC, cfg, value, frontier, mesh)
    return Result(to_original(pg, v).astype(np.int64), stats, trace=trace)


def spmv(pg: PartitionedGraph, x: np.ndarray,
         cfg: EngineConfig = EngineConfig(), mesh=None) -> Result:
    """Push-mode y[dst] += val * x[src] — one engine epoch."""
    value, frontier = init_add_state(pg, x)
    _, acc, stats, trace = _call(pg, SPMV, cfg, value, frontier, mesh)
    return Result(to_original(pg, acc).astype(np.float64), stats,
                  trace=trace)


def pagerank(pg: PartitionedGraph, damping: float = 0.85, iters: int = 20,
             tol: float = 0.0, cfg: EngineConfig = EngineConfig(),
             mesh=None) -> Result:
    """Epoch-synchronized PageRank (the paper keeps the barrier for PR).

    Each epoch is one engine run (push contributions, accumulate); the rank
    update + dangling redistribution happen between epochs — the host-driven
    barrier the paper describes reusing the chip-idle signal for.
    """
    V = pg.num_vertices
    real = real_mask(pg)
    deg = np.asarray(pg.deg)
    rank = np.where(real, np.float32(1.0 / V), 0.0).astype(np.float32)
    # telemetry shapes depend on the NoC backend; a backend-shaped zero is
    # always safe to accumulate (also the iters == 0 result).
    total = zero_stats(cfg, pg.T, PAGERANK)
    epochs = 0
    trace = None  # the LAST epoch's ring (each epoch restarts the engine)
    for _ in range(iters):
        frontier = jnp.asarray(real & (deg > 0))
        _, acc, stats, trace = _call(pg, PAGERANK, cfg, jnp.asarray(rank),
                                     frontier, mesh)
        acc = np.asarray(acc)
        dangling = rank[real & (deg == 0)].sum()
        new_rank = np.where(
            real, (1 - damping) / V + damping * (acc + dangling / V),
            0.0).astype(np.float32)
        diff = np.abs(new_rank - rank).sum()
        rank = new_rank
        total = _acc_stats(total, stats)
        epochs += 1
        if tol and diff < tol:
            break
    return Result(to_original(pg, rank).astype(np.float64), total, epochs,
                  trace=trace)


def kcore(pg: PartitionedGraph, k: int, cfg: EngineConfig = EngineConfig(),
          mesh=None) -> Result:
    """k-core membership by peeling (graph must be symmetric, deduped).

    values[v] = 1 if v survives in the k-core, else 0.  The engine peels
    asynchronously (or per BSP epoch): removed vertices emit one decrement
    per edge and the threshold fold re-arms the frontier — the same
    3-channel shape as BFS with a different T3.
    """
    value, frontier, acc = init_kcore_state(pg, k)
    _, a, stats, trace = _call(pg, kcore_program(int(k)), cfg, value,
                               frontier, mesh, acc=acc)
    member = (to_original(pg, a) == 0.0).astype(np.int64)
    return Result(member, stats, trace=trace)


def sort_adjacency(pg: PartitionedGraph) -> PartitionedGraph:
    """Sort every per-vertex edge segment by placed destination id.

    Factored out of :func:`prepare_triangles` so a migration pass
    (repro.place) can restore the ``sorted_adj`` layout after re-dealing
    segments: the sort key is the *placed* destination, so it must be
    re-applied whenever the owner map changes."""
    dst = np.asarray(pg.edge_dst).copy()
    val = np.asarray(pg.edge_val).copy()
    degs = np.asarray(pg.deg)
    for t in range(pg.T):
        total = int(degs[t].sum())
        seg = np.full(pg.e_chunk, np.iinfo(np.int64).max, np.int64)
        seg[:total] = np.repeat(np.arange(pg.v_chunk), degs[t])
        order = np.lexsort((dst[t], seg))
        dst[t] = dst[t][order]
        val[t] = val[t][order]
    return dataclasses.replace(pg, edge_dst=jnp.asarray(dst, jnp.int32),
                               edge_val=jnp.asarray(val, jnp.float32),
                               sorted_adj=True)


def prepare_triangles(g: CSRGraph, T: int,
                      scheme: str = "low_order") -> PartitionedGraph:
    """Partition for triangle counting: vertex-aligned edges (each tile
    owns its vertices' full adjacency) with every per-vertex segment sorted
    by placed destination, so the closing-edge check is a local binary
    search.  ``g`` must be symmetric and deduplicated (use
    :func:`symmetrize`)."""
    return sort_adjacency(partition_graph(g, T, scheme,
                                          edge_mode="vertex_aligned"))


def triangles(pg: PartitionedGraph, cfg: EngineConfig = EngineConfig(),
              mesh=None) -> Result:
    """2-hop triangle counting on a :func:`prepare_triangles` partition.

    values[v] = number of triangles whose placed-minimum vertex is v
    (each triangle counted exactly once; ``values.sum()`` is the total).
    A 4-channel program: range -> wedge at the neighbor's owner -> second
    range -> intersection-count fold.
    """
    # the close fold binary-searches each vertex's local sorted adjacency —
    # any other partition layout would silently miscount.
    assert pg.edge_mode == "vertex_aligned" and pg.sorted_adj, (
        "triangles() needs a prepare_triangles partition (vertex-aligned "
        f"edges, sorted segments); got edge_mode={pg.edge_mode!r}, "
        f"sorted_adj={pg.sorted_adj}")
    cfg = sized_cfg(cfg, TRIANGLES, pg.T)
    real = real_mask(pg)
    deg = np.asarray(pg.deg)
    value = jnp.zeros((pg.T, pg.v_chunk), jnp.float32)
    frontier = jnp.asarray(real & (deg > 0))
    _, a, stats, trace = _call(pg, TRIANGLES, cfg, value, frontier, mesh)
    return Result(to_original(pg, a).astype(np.int64), stats, trace=trace)


def _acc_stats(a: Stats, b: Stats) -> Stats:
    """Combine per-epoch Stats: counters add, peaks take the max.

    Shape-checked: telemetry arrays are shaped by the NoC backend and the
    channel counters by the program — accumulating mismatched runs (or a
    default ``Stats.zero()``) is a bug, not a broadcast.
    """
    for name, x, y in zip(Stats._fields, a, b):
        if jnp.shape(x) != jnp.shape(y):
            raise ValueError(
                f"Stats.{name} shape mismatch {jnp.shape(x)} vs "
                f"{jnp.shape(y)}: accumulating stats from different NoC "
                f"backends/programs? Use zero_stats(cfg, T, alg) instead "
                f"of Stats.zero().")
    merged = jax.tree.map(lambda x, y: x + y, a, b)
    return merged._replace(
        max_link_occupancy=jnp.maximum(a.max_link_occupancy,
                                       b.max_link_occupancy))


# --------------------------------------------------------------------------
# Convenience: build + partition + symmetrize.
# --------------------------------------------------------------------------

def symmetrize(g: CSRGraph) -> CSRGraph:
    src = np.repeat(np.arange(g.num_vertices), g.ptr[1:] - g.ptr[:-1])
    s2 = np.concatenate([src, g.dst])
    d2 = np.concatenate([g.dst, src])
    v2 = np.concatenate([g.val, g.val])
    return CSRGraph.from_edges(g.num_vertices, s2, d2, v2, dedup=True)


def prepare(g: CSRGraph, T: int, scheme: str = "low_order",
            edge_mode: str = "equal_edges",
            dies: tuple[int, int] | None = None) -> PartitionedGraph:
    """``dies=(ndies_y, ndies_x)`` is required by the ``*_dielocal``
    placement schemes and must match the hier NoC geometry
    (``EngineConfig.ndies_y/ndies_x``) for partitions to be die-resident
    on the fabric that runs them."""
    return partition_graph(g, T, scheme, edge_mode, dies=dies)
