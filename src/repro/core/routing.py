"""The Dalorex task-routing primitive.

This is the JAX-native analogue of the paper's headerless NoC (Section
III-E/F).  A *task message* is a fixed-width row of int32 flits whose first
flit is a **global array index**; ownership of that index under the static
equal-chunk distribution *is* the route — no metadata is sent, exactly like
the paper's head-flit encoding.  We take the idea one step further: slot
*emptiness* is also encoded in the head flit (index < 0), so a routing round
exchanges exactly one buffer — no side-band validity traffic.

This module is the single-exchange *primitive*; the engine routes through
the pluggable :mod:`repro.noc` subsystem, whose ``IdealAllToAll`` backend
is exactly one :func:`route_tasks` round and whose physical backends
(mesh / torus / ruche) compose :func:`bin_by_owner` + ``comm.a2a`` into
dimension-ordered per-axis exchanges with per-link backpressure.

``route_tasks`` performs one network round:

1. each device bins its outgoing messages by destination shard
   (``owner = idx // chunk`` in placed space — the paper's head encoder),
2. claims per-destination slots up to ``capacity`` (the channel-queue bound;
   the paper's routers stall, we *spill* and replay — same backpressure
   semantics, no loss),
3. exchanges the binned buffer with ONE ``all_to_all`` (the vectorized
   wormhole transfer), and
4. returns the received messages plus the spilled ones for local re-queueing.

Slot claiming is FIFO per destination (``occurrence_index``), matching the
in-order per-channel delivery of the paper's wormhole NoC.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queues import occurrence_index, histogram

EMPTY = jnp.int32(-1)  # head-flit value marking an empty network slot


class Routed(NamedTuple):
    """Result of one routing round (all shapes static).

    recv:        (T*capacity, W) int32 — received messages, grouped by source
                 device; empty slots have head flit < 0.
    recv_valid:  (T*capacity,) bool — decoded from the head flit.
    spill:       (N, W) int32 — local copies of messages that did not fit.
    spill_valid: (N,) bool.
    sent:        () int32 — number of messages actually sent by this device.
    """

    recv: jax.Array
    recv_valid: jax.Array
    spill: jax.Array
    spill_valid: jax.Array
    sent: jax.Array


def bin_by_owner(msgs, valid, dest, num_shards, capacity):
    """Pack ``msgs`` into per-destination slots of a (T*capacity, W) buffer.

    Returns (send_buf, spill_msgs, spill_valid, n_sent).  Rows
    ``[d*capacity:(d+1)*capacity]`` of ``send_buf`` are addressed to shard
    ``d``; empty slots have head flit -1.  FIFO order within each destination
    is preserved; messages beyond ``capacity`` for a destination are returned
    as spill (masked in place).
    """
    n, w = msgs.shape
    occ = occurrence_index(dest, valid, num_shards)  # >= n for invalid rows
    fits = valid & (occ < capacity)
    slot = jnp.where(fits, dest * capacity + occ, num_shards * capacity)
    buf = jnp.full((num_shards * capacity + 1, w), EMPTY, jnp.int32)
    buf = buf.at[slot].set(msgs)
    spill_valid = valid & ~fits
    n_sent = fits.sum(dtype=jnp.int32)
    return buf[:-1], msgs, spill_valid, n_sent


def route_tasks(comm, msgs: jax.Array, valid: jax.Array, dest: jax.Array,
                capacity: int) -> Routed:
    """One Dalorex network round over ``comm`` (AxisComm or LocalComm).

    Under ``LocalComm`` every array carries a leading T axis and local stages
    are vmapped; under ``AxisComm`` this runs inside shard_map per device.
    """
    T = comm.size

    def local_bin(_me, m, v, d):
        return bin_by_owner(m, v, d, T, capacity)

    buf, spill, spill_valid, n_sent = comm.run(local_bin, msgs, valid, dest)
    recv = comm.a2a(buf)
    recv_valid = recv[..., 0] >= 0
    return Routed(recv, recv_valid, spill, spill_valid, n_sent)


def route_stats(comm, valid: jax.Array, dest: jax.Array, num_shards: int):
    """Per-destination message histogram (for NoC-balance benchmarks)."""
    def local(_me, v, d):
        return histogram(d, v, num_shards)
    return comm.run(local, valid, dest)
