"""Dalorex-routed Mixture-of-Experts dispatch.

Tokens are task messages; experts are the immovable data.  Expert placement
uses the paper's uniform low-order scattering over the ``model`` axis:

  * E >= M ("moonshot": 64 experts / 16 shards) — expert ``e`` lives on shard
    ``e mod M`` at local slot ``e div M`` (Eps = E/M local experts).
  * E <  M ("mixtral": 8 experts / 16 shards) — each expert is
    *tensor-split*: shard ``m`` holds ff-slice ``m div E`` of expert
    ``m mod E`` (tp = M/E slices).  A token sends ``tp`` messages; the
    partial w_down outputs sum at the source — exact TP, no replica
    divergence, memory fully sharded.

Dispatch is the engine's slot-claiming (``occurrence_index``) + ONE
all_to_all each way; per-destination capacity is the paper's bounded channel
queue.  Overflowed tokens pass through on the residual (counted — the
telemetry the TSU would expose).  The same per-device code runs single-device
(M=1, a2a = identity) for smoke tests, and :func:`moe_dense_oracle` is the
drop-free reference the dispatch must match when nothing overflows.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import shard_map_compat
from repro.core.queues import occurrence_index
from repro.parallel.sharding import ParamSpec, current_mesh, current_rules


@dataclasses.dataclass(frozen=True)
class MoEDims:
    E: int          # experts
    k: int          # experts per token
    M: int          # model-axis shards
    d: int
    ff: int
    mlp: str        # swiglu | squared_relu | gelu

    @property
    def eps(self) -> int:  # local experts per shard
        return max(self.E // self.M, 1)

    @property
    def tp(self) -> int:   # ff slices per expert (E < M)
        return max(self.M // self.E, 1)

    @property
    def ff_local(self) -> int:
        return self.ff // self.tp

    @property
    def slots(self) -> int:  # global expert-slot axis (leading param axis)
        return self.M * self.eps

    def check(self):
        assert self.E % self.M == 0 or self.M % self.E == 0, (self.E, self.M)


def moe_param_specs(d: int, ff: int, E: int, M: int, mlp: str, dtype: str):
    # "expert_ff" resolves to None under training rules (d gets FSDP) and to
    # "data" under decode rules (weights-stationary 2D expert sharding)
    dims = MoEDims(E, 0, M, d, ff, mlp)
    g, ffl = dims.slots, dims.ff_local
    specs = {"router": ParamSpec((d, E), (None, None), "float32")}
    if mlp == "swiglu":
        specs["w_gate"] = ParamSpec((g, d, ffl),
                                    ("expert", "fsdp", "expert_ff"), dtype)
    specs["w_up"] = ParamSpec((g, d, ffl), ("expert", "fsdp", "expert_ff"),
                              dtype)
    specs["w_down"] = ParamSpec((g, ffl, d), ("expert", "expert_ff", "fsdp"),
                                dtype)
    return specs


def _expert_ffn(params, x, dims: MoEDims):
    """x: (..., d) -> (..., d) through ONE expert's (sliced) FFN.
    params leaves have a leading local-slot axis handled by the caller."""
    if dims.mlp == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("...d,df->...f", x, params["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
    else:
        u = jnp.einsum("...d,df->...f", x, params["w_up"],
                       preferred_element_type=jnp.float32)
        h = (jnp.square(jax.nn.relu(u)) if dims.mlp == "squared_relu"
             else jax.nn.gelu(u)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _router(params, x, dims: MoEDims):
    """Returns (gates (n,k), experts (n,k) int32, aux_loss scalar)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, dims.k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = jnp.zeros((dims.E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (x.shape[0] * dims.k))
    p = probs.mean(0)
    aux = dims.E * jnp.sum(f * p)
    return gates.astype(x.dtype), top_e.astype(jnp.int32), aux


def _dispatch_local(params, x, dims: MoEDims, capacity: int,
                    a2a, psum):
    """Per-device MoE block.  x: (n, d) local tokens.

    Single-level slot claiming (§Perf moonshot iteration): entries claim a
    slot of their GLOBAL expert slot directly — per-destination rows are
    contiguous (dest d owns rows [d·eps·cap_e, (d+1)·eps·cap_e)), so ONE
    payload all_to_all delivers tokens already grouped by local expert.  No
    metadata flits at all (the slot position IS the expert id — the
    headerless-NoC idea one level deeper), no second binning pass, no 2x
    staging buffer.

    ``capacity`` is per destination; per-expert capacity = capacity // eps.
    Returns (y (n, d), aux_loss, overflow) — aux/overflow reduced by the
    caller-provided psum.
    """
    n, d = x.shape
    gates, experts, aux = _router(params, x, dims)
    k, tp, M, eps = dims.k, dims.tp, dims.M, dims.eps
    cap_e = max(1, capacity // eps)   # slots per expert
    n_slots = M * eps

    # entries: (n*k*tp,) — token i, choice c, slice j
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k * tp)
    e = jnp.repeat(experts.reshape(-1), tp)                # (n*k*tp,)
    j = jnp.tile(jnp.arange(tp, dtype=jnp.int32), n * k)
    gate = jnp.repeat(gates.reshape(-1), tp)
    if dims.E >= M:
        dest = e % M
        le = e // M
    else:
        dest = e + j * dims.E
        le = jnp.zeros_like(e)
    g = dest * eps + le                                    # global slot
    valid = jnp.ones_like(dest, dtype=bool)

    occ = occurrence_index(g, valid, n_slots)
    fits = occ < cap_e
    slot = jnp.where(fits, g * cap_e + occ, n_slots * cap_e)
    overflow = (~fits).sum(dtype=jnp.int32)

    payload = jnp.zeros((n_slots * cap_e + 1, d), x.dtype).at[slot].set(
        x[tok])
    recv = a2a(payload[:-1])                      # (M*eps*cap_e, d)

    local = {key: v for key, v in params.items() if key != "router"}
    if eps == 1:
        out = _expert_ffn(jax.tree.map(lambda a: a[0], local), recv, dims)
    else:
        # rows arrive grouped source-major: (M, eps, cap_e, d) — regroup
        # per local expert with one transpose, batch the expert FFNs
        grouped = recv.reshape(M, eps, cap_e, d).transpose(1, 0, 2, 3)
        grouped = grouped.reshape(eps, M * cap_e, d)
        out_e = jax.vmap(lambda p, xx: _expert_ffn(p, xx, dims))(
            local, grouped)
        out = out_e.reshape(eps, M, cap_e, d).transpose(1, 0, 2, 3)
        out = out.reshape(M * eps * cap_e, d)

    back = a2a(out)  # results return to their claim slots
    contrib = jnp.take(back, jnp.minimum(slot, n_slots * cap_e - 1), axis=0)
    contrib = jnp.where(fits[:, None], contrib, 0)
    y = jnp.zeros((n, d), jnp.float32).at[tok].add(
        contrib.astype(jnp.float32) * gate[:, None].astype(jnp.float32))
    return y.astype(x.dtype), psum(aux) / M, psum(overflow)


def _dispatch_resident(params, x, dims: MoEDims, capacity: int, my_idx,
                       model_axis: str = "model"):
    """No-network dispatch for replicated tokens (decode serving).

    x: (n, d) — the SAME tokens on every shard.  This shard computes only
    the entries owned by its expert slots / ff slice; the caller psums the
    per-shard partial y over (model, ff) axes.  aux/ovf are psum-free
    (identical math on every shard).
    """
    n, d = x.shape
    gates, experts, aux = _router(params, x, dims)
    k, tp, M, eps = dims.k, dims.tp, dims.M, dims.eps

    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k * tp)
    e = jnp.repeat(experts.reshape(-1), tp)
    j = jnp.tile(jnp.arange(tp, dtype=jnp.int32), n * k)
    gate = jnp.repeat(gates.reshape(-1), tp)
    if dims.E >= M:
        dest = e % M
        le = e // M
    else:
        dest = e + j * dims.E
        le = jnp.zeros_like(e)
    mine = dest == my_idx

    # claim local slots: (eps, cap_e) buffer for this shard only
    cap_e = max(1, (2 * capacity * M) // (M * eps))
    occ = occurrence_index(jnp.where(mine, le, eps), mine, eps)
    fits = mine & (occ < cap_e)
    slot = jnp.where(fits, le * cap_e + occ, eps * cap_e)
    # overflow counted once per token-entry across the grid: only the owner
    # shard counts it, and the caller's replicated out_spec is satisfied
    # because every shard computes the same mine/fits masks for ITS index —
    # psum over model in the caller... aux is identical; ovf differs per
    # shard, so reduce it here.
    overflow = (mine & ~fits).sum(dtype=jnp.int32)
    overflow = jax.lax.psum(overflow, model_axis)

    payload = jnp.zeros((eps * cap_e + 1, d), x.dtype).at[slot].set(x[tok])
    buf = payload[:-1].reshape(eps, cap_e, d)
    local = {key: v for key, v in params.items() if key != "router"}
    if eps == 1:
        out_e = _expert_ffn(jax.tree.map(lambda a: a[0], local), buf[0],
                            dims)[None]
    else:
        out_e = jax.vmap(lambda p, xx: _expert_ffn(p, xx, dims))(local, buf)
    out_flat = out_e.reshape(eps * cap_e, d)
    contrib = jnp.take(out_flat, jnp.minimum(slot, eps * cap_e - 1), axis=0)
    contrib = jnp.where(fits[:, None], contrib, 0)
    y = jnp.zeros((n, d), jnp.float32).at[tok].add(
        contrib.astype(jnp.float32) * gate[:, None].astype(jnp.float32))
    return y.astype(x.dtype), aux, overflow


def moe_block(params, x, *, E: int, k: int, ff: int, mlp: str,
              model_axis: str = "model", batch_axes=("data",),
              seq_shard: bool = True, capacity_factor: float = 1.25):
    """x: (B, S, d).  Runs the Dalorex dispatch as a shard_map island under a
    mesh context, or single-device (M=1) otherwise.  Returns (y, aux, ovf).
    """
    B, S, d = x.shape
    mesh = current_mesh()
    if mesh is None:
        dims = MoEDims(E, k, 1, d, ff, mlp)
        dims.check()
        n = B * S
        cap = max(1, int(n * k * dims.tp * capacity_factor))
        y, aux, ovf = _dispatch_local(
            params, x.reshape(n, d), dims, cap,
            a2a=lambda a: a, psum=lambda a: a)
        return y.reshape(B, S, d), aux, ovf

    M = mesh.shape[model_axis]
    dims = MoEDims(E, k, M, d, ff, mlp)
    dims.check()
    # weights-stationary 2D expert sharding (decode rules): the expert ff
    # dimension is sharded over these axes; every such shard replicates the
    # dispatch and computes its ff-slice; partial outputs psum at the end.
    rules = current_rules()
    ff_axes = rules.get("expert_ff") if rules is not None else None
    if ff_axes is not None and not isinstance(ff_axes, tuple):
        ff_axes = (ff_axes,)
    ffd = 1
    if ff_axes:
        for a in ff_axes:
            ffd *= mesh.shape[a]
    if ffd > 1:
        # Decode weights-stationary path (§Perf iter 2): tokens are
        # replicated across the whole (model x ff) grid, so NO dispatch
        # network round is needed at all — each shard locally selects the
        # tokens owned by its expert slots (the Dalorex move in its purest
        # form: data never moves, the task shows up where the data is),
        # computes its ff-slice, and ONE psum over (model, ff) combines
        # expert-parallel partials and ff-slice partials together.
        n_local = B * S
        capacity = max(1, int(n_local * k * dims.tp * capacity_factor) // M)

        def body2(prm, xb):
            xl = xb.reshape(-1, d)
            y, aux, ovf = _dispatch_resident(
                prm, xl, dims, capacity,
                my_idx=jax.lax.axis_index(model_axis),
                model_axis=model_axis)
            y = jax.lax.psum(y, (model_axis,) + ff_axes)
            return y.reshape(xb.shape), aux, ovf

        ffspec = ff_axes if len(ff_axes) > 1 else ff_axes[0]
        pspec = {}
        for key in params:
            if key == "router":
                pspec[key] = P(None, None)
            elif key == "w_down":
                pspec[key] = P(model_axis, ffspec, None)
            else:
                pspec[key] = P(model_axis, None, ffspec)
        fn = shard_map_compat(
            body2, mesh=mesh,
            in_specs=(pspec, P(None, None, None)),
            out_specs=(P(None, None, None), P(), P()))
        return fn(params, x)

    # drop non-divisible shardings (e.g. batch=1 long-context decode)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if B % dp != 0 or B < dp:
        batch_axes, dp = (), 1
    if S % M != 0 or S < M:
        seq_shard = False
    n_local = (B // dp) * (S // (M if seq_shard else 1))
    capacity = max(1, int(n_local * k * dims.tp * capacity_factor) // M)

    bspec = (tuple(batch_axes) if len(batch_axes) > 1
             else batch_axes[0] if batch_axes else None)
    sspec = model_axis if seq_shard else None

    def body(prm, xb):
        xl = xb.reshape(-1, d)
        y, aux, ovf = _dispatch_local(
            prm, xl, dims, capacity,
            a2a=lambda a: jax.lax.all_to_all(a, model_axis, 0, 0, tiled=True),
            psum=lambda a: jax.lax.psum(a, model_axis))
        return y.reshape(xb.shape), aux, ovf

    pspec = {key: P(model_axis, None, None) for key in params
             if key != "router"}
    pspec["router"] = P(None, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(pspec, P(bspec, sspec, None)),
        out_specs=(P(bspec, sspec, None), P(), P()))
    return fn(params, x)


def to_dispatch_layout(params, E: int, M: int):
    """Convert oracle layout (E, d, ff) to the placed dispatch layout
    (M*eps, d, ff_local) — the low-order expert scattering of Section III-A.

    E >= M: slot m*eps+le holds expert le*M + m.
    E <  M: slot m        holds ff-slice m//E of expert m%E.
    """
    import numpy as np
    out = {"router": params["router"]}
    eps, tp = max(E // M, 1), max(M // E, 1)
    for key, w in params.items():
        if key == "router":
            continue
        w = np.asarray(w)
        ff_axis = 2 if key != "w_down" else 1
        ffl = w.shape[ff_axis] // tp
        slots = []
        for m in range(M):
            for le in range(eps):
                if E >= M:
                    slots.append(w[le * M + m])
                else:
                    j = m // E
                    sl = [slice(None)] * 3
                    sl[ff_axis] = slice(j * ffl, (j + 1) * ffl)
                    slots.append(w[m % E][tuple(sl[1:])])
        out[key] = jnp.asarray(np.stack(slots))
    return out


def moe_dense_oracle(params, x, *, E: int, k: int, ff: int, mlp: str):
    """Drop-free reference: every token computes ALL experts densely and
    mixes with its top-k gates.  Used by tests to validate the dispatch
    (must match exactly when overflow == 0).  Single-device only (params in
    the M=1 layout, i.e. leading slot axis == E, full ff)."""
    B, S, d = x.shape
    dims = MoEDims(E, k, 1, d, ff, mlp)
    xt = x.reshape(-1, d)
    gates, experts, aux = _router(params, xt, dims)
    local = {key: v for key, v in params.items() if key != "router"}
    outs = jax.vmap(lambda p: _expert_ffn(p, xt, dims))(
        jax.tree.map(lambda a: a, local))  # (E, n, d)
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)  # (n, k, E)
    w = (onehot * gates[..., None].astype(jnp.float32)).sum(1)  # (n, E)
    y = jnp.einsum("ne,end->nd", w, outs.astype(jnp.float32))
    return y.reshape(B, S, d).astype(x.dtype), aux
