"""Data distribution: the paper's equal-chunk placement arithmetic.

Dalorex distributes every dataset array in equal chunks across tiles and
routes task messages by the *global array index* alone (headerless NoC,
Section III-E): ``owner(i) = i // chunk`` and ``local(i) = i % chunk`` once
the placement permutation has been applied.

Three placement schemes are provided (the Fig. 5 ``Uniform-distr``
ablation plus the paper's degree-aware preprocessing rung):

* ``low_order``  — Dalorex: original element ``v`` goes to shard ``v % T``
  (scatter by low-order bits). Consecutive hot vertices land on different
  tiles, balancing work and traffic without preprocessing.
* ``high_order`` — Tesseract-like: contiguous chunks (``v // chunk``), which
  concentrates hub neighborhoods (and therefore traffic) on few tiles.
* ``degree_interleave`` — degree-aware: vertices sorted by descending
  degree are dealt round-robin across tiles, so the T highest-degree hubs
  land on T *different* tiles.  This is the preprocessing-heavy rung the
  paper contrasts with low-order bits: it equalizes per-tile *work*
  (``work_max``) even under adversarial (degree-sorted) vertex ids, at the
  cost of a host-side sort.  Requires per-vertex degrees (``deg=``).

Each scheme also has a **die-local** variant (``low_order_dielocal``,
``high_order_dielocal``, ``degree_interleave_dielocal``) for the
multi-die hierarchical NoC (``noc="hier"``, PIUMA-style die-of-dies):
the padded ID space is first cut into one contiguous *partition* per die
(so each graph partition stays die-resident), then the base scheme is
applied *within* the die across that die's tiles.  Die membership of the
tiles comes from ``tile_die=`` (built by ``repro.noc.tile_die_map`` so
placement and fabric agree on the geometry); die crossings — the scarce,
expensive resource of the hierarchy — then only happen on edges that
leave a partition, not on every consecutive-id hop the flat ``low_order``
scatter takes.

We realize a scheme as a *permutation into placed-ID space* followed by
contiguous chunking, which is exactly how the paper builds its global CSR
("we build the global CSR so that consecutive vertices fall into different
tiles").
"""
from __future__ import annotations

import dataclasses

import numpy as np


def padded_len(n: int, shards: int) -> int:
    return ((n + shards - 1) // shards) * shards


@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Equal-chunk distribution of a (padded) global array over shards."""

    total: int  # padded global length; multiple of num_shards
    num_shards: int

    def __post_init__(self):
        assert self.total % self.num_shards == 0, (self.total, self.num_shards)

    @property
    def chunk(self) -> int:
        return self.total // self.num_shards

    def owner(self, idx):
        return idx // self.chunk

    def local(self, idx):
        return idx % self.chunk

    def global_(self, shard, local):
        return shard * self.chunk + local


DIELOCAL_SUFFIX = "_dielocal"


def _rank_by_degree(deg_padded: np.ndarray) -> np.ndarray:
    """rank[i] of every id by descending degree (stable: equal-degree ids
    keep id order, so the zero-degree padding ids rank last)."""
    order = np.argsort(-deg_padded, kind="stable")
    rank = np.empty(len(deg_padded), np.int64)
    rank[order] = np.arange(len(deg_padded), dtype=np.int64)
    return rank


def _dielocal_place(ids, n_orig: int, chunk: int, base: str,
                    deg: np.ndarray | None,
                    tile_die: np.ndarray) -> np.ndarray:
    """Die-local placement: contiguous ID partitions pinned to dies, the
    base scheme applied within each die over that die's tiles."""
    n_pad = len(ids)
    tile_die = np.asarray(tile_die, np.int64)
    n_dies = int(tile_die.max()) + 1
    counts = np.bincount(tile_die, minlength=n_dies)
    if not (counts == counts[0]).all():
        raise ValueError(f"dies must hold equal tile counts, got {counts}")
    t_die = int(counts[0])                       # tiles per die
    tiles_of = np.argsort(tile_die, kind="stable").reshape(n_dies, t_die)
    sc = n_pad // n_dies                         # ids per die partition
    d, o = ids // sc, ids % sc
    if base == "low_order":
        lt, slot = o % t_die, o // t_die
    elif base == "high_order":
        lt, slot = o // chunk, o % chunk
    elif base == "degree_interleave":
        if deg is None:
            raise ValueError("degree_interleave placement needs deg=")
        assert len(deg) == n_orig, (len(deg), n_orig)
        degp = np.zeros(n_pad, np.int64)
        degp[:n_orig] = np.asarray(deg, np.int64)
        rank = np.concatenate([_rank_by_degree(degp[i * sc:(i + 1) * sc])
                               for i in range(n_dies)])
        lt, slot = rank % t_die, rank // t_die
    else:
        raise ValueError(f"unknown placement scheme: {base}{DIELOCAL_SUFFIX}")
    return tiles_of[d, lt] * chunk + slot


def placement(n_orig: int, num_shards: int, scheme: str,
              deg: np.ndarray | None = None,
              tile_die: np.ndarray | None = None,
              ) -> tuple[np.ndarray, np.ndarray]:
    """Return (place, inv) arrays over the padded ID space.

    ``place[v]`` is the placed ID of original element ``v``;
    ``inv[p]`` is the original ID at placed slot ``p`` (or -1 for padding).
    ``deg`` (per-original-element weights) is required by the degree-aware
    ``degree_interleave`` scheme(s) and ignored otherwise; ``tile_die``
    (a (num_shards,) tile -> die map, see ``repro.noc.tile_die_map``) is
    required by the ``*_dielocal`` schemes and ignored otherwise.
    """
    n_pad = padded_len(n_orig, num_shards)
    ids = np.arange(n_pad, dtype=np.int64)
    chunk = n_pad // num_shards
    if scheme.endswith(DIELOCAL_SUFFIX):
        if tile_die is None:
            raise ValueError(f"{scheme} placement needs tile_die=")
        if len(tile_die) != num_shards:
            raise ValueError(f"tile_die maps {len(tile_die)} tiles, "
                             f"placement has {num_shards} shards")
        place = _dielocal_place(ids, n_orig, chunk,
                                scheme[: -len(DIELOCAL_SUFFIX)], deg,
                                tile_die)
    elif scheme == "low_order":
        place = (ids % num_shards) * chunk + ids // num_shards
    elif scheme == "high_order":
        place = ids.copy()
    elif scheme == "degree_interleave":
        if deg is None:
            raise ValueError("degree_interleave placement needs deg=")
        assert len(deg) == n_orig, (len(deg), n_orig)
        # rank 0 = highest degree; padding ids rank last.  Stable sort keeps
        # equal-degree vertices in id order (deterministic).
        order = np.argsort(-np.asarray(deg, np.int64), kind="stable")
        order = np.concatenate([order, np.arange(n_orig, n_pad)])
        rank = np.empty(n_pad, np.int64)
        rank[order] = ids
        # deal ranks round-robin: rank r -> tile r % T, slot r // T
        place = (rank % num_shards) * chunk + rank // num_shards
    else:
        raise ValueError(f"unknown placement scheme: {scheme}")
    inv = np.full(n_pad, -1, dtype=np.int64)
    inv[place] = ids
    # mark padding slots
    pad_mask = inv >= n_orig
    inv[pad_mask] = -1
    return place[:n_orig].astype(np.int64), inv
