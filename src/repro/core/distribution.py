"""Data distribution: the paper's equal-chunk placement arithmetic.

Dalorex distributes every dataset array in equal chunks across tiles and
routes task messages by the *global array index* alone (headerless NoC,
Section III-E): ``owner(i) = i // chunk`` and ``local(i) = i % chunk`` once
the placement permutation has been applied.

Three placement schemes are provided (the Fig. 5 ``Uniform-distr``
ablation plus the paper's degree-aware preprocessing rung):

* ``low_order``  — Dalorex: original element ``v`` goes to shard ``v % T``
  (scatter by low-order bits). Consecutive hot vertices land on different
  tiles, balancing work and traffic without preprocessing.
* ``high_order`` — Tesseract-like: contiguous chunks (``v // chunk``), which
  concentrates hub neighborhoods (and therefore traffic) on few tiles.
* ``degree_interleave`` — degree-aware: vertices sorted by descending
  degree are dealt round-robin across tiles, so the T highest-degree hubs
  land on T *different* tiles.  This is the preprocessing-heavy rung the
  paper contrasts with low-order bits: it equalizes per-tile *work*
  (``work_max``) even under adversarial (degree-sorted) vertex ids, at the
  cost of a host-side sort.  Requires per-vertex degrees (``deg=``).

We realize a scheme as a *permutation into placed-ID space* followed by
contiguous chunking, which is exactly how the paper builds its global CSR
("we build the global CSR so that consecutive vertices fall into different
tiles").
"""
from __future__ import annotations

import dataclasses

import numpy as np


def padded_len(n: int, shards: int) -> int:
    return ((n + shards - 1) // shards) * shards


@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Equal-chunk distribution of a (padded) global array over shards."""

    total: int  # padded global length; multiple of num_shards
    num_shards: int

    def __post_init__(self):
        assert self.total % self.num_shards == 0, (self.total, self.num_shards)

    @property
    def chunk(self) -> int:
        return self.total // self.num_shards

    def owner(self, idx):
        return idx // self.chunk

    def local(self, idx):
        return idx % self.chunk

    def global_(self, shard, local):
        return shard * self.chunk + local


def placement(n_orig: int, num_shards: int, scheme: str,
              deg: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Return (place, inv) arrays over the padded ID space.

    ``place[v]`` is the placed ID of original element ``v``;
    ``inv[p]`` is the original ID at placed slot ``p`` (or -1 for padding).
    ``deg`` (per-original-element weights) is required by the degree-aware
    ``degree_interleave`` scheme and ignored otherwise.
    """
    n_pad = padded_len(n_orig, num_shards)
    ids = np.arange(n_pad, dtype=np.int64)
    chunk = n_pad // num_shards
    if scheme == "low_order":
        place = (ids % num_shards) * chunk + ids // num_shards
    elif scheme == "high_order":
        place = ids.copy()
    elif scheme == "degree_interleave":
        if deg is None:
            raise ValueError("degree_interleave placement needs deg=")
        assert len(deg) == n_orig, (len(deg), n_orig)
        # rank 0 = highest degree; padding ids rank last.  Stable sort keeps
        # equal-degree vertices in id order (deterministic).
        order = np.argsort(-np.asarray(deg, np.int64), kind="stable")
        order = np.concatenate([order, np.arange(n_orig, n_pad)])
        rank = np.empty(n_pad, np.int64)
        rank[order] = ids
        # deal ranks round-robin: rank r -> tile r % T, slot r // T
        place = (rank % num_shards) * chunk + rank // num_shards
    else:
        raise ValueError(f"unknown placement scheme: {scheme}")
    inv = np.full(n_pad, -1, dtype=np.int64)
    inv[place] = ids
    # mark padding slots
    pad_mask = inv >= n_orig
    inv[pad_mask] = -1
    return place[:n_orig].astype(np.int64), inv
