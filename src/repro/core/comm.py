"""Communication backends for the Dalorex engine.

The engine's per-round code is written as *per-device local stages* glued by
collectives. Two interchangeable backends run the identical stage code:

* :class:`AxisComm` — real SPMD execution inside ``jax.shard_map`` over a
  named mesh axis (this is what runs on pods and in the dry-run).
* :class:`LocalComm` — single-device emulation where "devices" are a leading
  array axis; local stages are ``vmap``-ed and the all-to-all is a transpose.
  This gives fast, exact unit/property tests of the full engine on one CPU
  device, with bit-identical semantics to the SPMD path.

The all-to-all convention follows the probe of ``jax.lax.all_to_all`` with
``tiled=True``: send buffers are ``(T*s, W)`` with rows ``[d*s:(d+1)*s]``
addressed to device ``d``; after exchange, rows ``[t*s:(t+1)*s]`` hold what
device ``t`` sent us. This is the vectorized form of the paper's headerless
NoC: the slot position encodes the route, no metadata flits are spent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AxisComm:
    """Collectives over a named shard_map axis."""

    axis: str
    size: int

    def a2a(self, x: jax.Array) -> jax.Array:
        # x: (T*s, ...) -> (T*s, ...)
        return jax.lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0, tiled=True)

    def psum(self, x):
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis)

    def all_gather(self, x):
        return jax.lax.all_gather(x, self.axis)  # adds leading T axis

    def my_id(self):
        return jax.lax.axis_index(self.axis)

    def run(self, fn, *args):
        """Run a per-device function (identity here; LocalComm vmaps)."""
        return fn(self.my_id(), *args)

    def to_global(self, x):
        """Collapse a replicated per-device value to one global copy.

        Under AxisComm a post-``psum``/``pmax`` value is already the global
        copy; under LocalComm it carries a broadcast leading T axis."""
        return x


@dataclasses.dataclass(frozen=True)
class LocalComm:
    """Single-device emulation: arrays carry a leading T axis."""

    size: int

    def a2a(self, x: jax.Array) -> jax.Array:
        # x: (T, T*s, ...) -> (T, T*s, ...)
        t = self.size
        s = x.shape[1] // t
        y = x.reshape((t, t, s) + x.shape[2:])
        y = jnp.swapaxes(y, 0, 1)
        return y.reshape((t, t * s) + x.shape[2:])

    def psum(self, x):
        # x: (T, ...) -> same value broadcast to all "devices"
        s = x.sum(axis=0, keepdims=True)
        return jnp.broadcast_to(s, x.shape)

    def pmax(self, x):
        s = x.max(axis=0, keepdims=True)
        return jnp.broadcast_to(s, x.shape)

    def all_gather(self, x):
        # x: (T, ...) -> (T, T, ...): every device sees the full stack
        return jnp.broadcast_to(x[None], (self.size,) + x.shape)

    def my_id(self):
        return jnp.arange(self.size, dtype=jnp.int32)

    def run(self, fn, *args):
        return jax.vmap(fn)(self.my_id(), *args)

    def to_global(self, x):
        """Collapse a broadcast (T, ...) per-device value to one copy."""
        return x[0]


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (>=0.6 top-level, older
    versions ship it as ``jax.experimental.shard_map`` with ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
