"""Sequential numpy oracles for the five paper workloads.

These are the "sequential x86 executions" the paper validates its simulator
against (Section IV-B).  Every engine test asserts bit-consistent results
(exact for BFS/WCC/SpMV path counts; allclose for SSSP/PageRank floats).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import CSRGraph


def bfs_ref(g: CSRGraph, root: int) -> np.ndarray:
    """Hop counts from root; unreachable = +inf."""
    dist = np.full(g.num_vertices, np.inf, np.float64)
    dist[root] = 0
    frontier = [root]
    d = 0
    while frontier:
        nxt = []
        for v in frontier:
            for e in range(g.ptr[v], g.ptr[v + 1]):
                u = g.dst[e]
                if dist[u] == np.inf:
                    dist[u] = d + 1
                    nxt.append(u)
        frontier, d = nxt, d + 1
    return dist


def sssp_ref(g: CSRGraph, root: int) -> np.ndarray:
    """Bellman-Ford (handles any nonnegative weights); unreachable = +inf."""
    import heapq
    dist = np.full(g.num_vertices, np.inf, np.float64)
    dist[root] = 0.0
    pq = [(0.0, root)]
    while pq:
        d, v = heapq.heappop(pq)
        if d > dist[v]:
            continue
        for e in range(g.ptr[v], g.ptr[v + 1]):
            u = g.dst[e]
            nd = d + g.val[e]
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(pq, (nd, u))
    return dist


def wcc_ref(g: CSRGraph) -> np.ndarray:
    """Weakly connected components: label = min original vertex id in the
    component.  Assumes ``g`` is already symmetrized (the harness does)."""
    n = g.num_vertices
    label = np.arange(n)
    # union-find with path compression
    def find(x):
        r = x
        while label[r] != r:
            r = label[r]
        while label[x] != r:
            label[x], x = r, label[x]
        return r
    for v in range(n):
        for e in range(g.ptr[v], g.ptr[v + 1]):
            a, b = find(v), find(g.dst[e])
            if a != b:
                if a < b:
                    label[b] = a
                else:
                    label[a] = b
    return np.array([find(v) for v in range(n)])


def pagerank_ref(g: CSRGraph, damping: float = 0.85, iters: int = 20
                 ) -> np.ndarray:
    """Power iteration with dangling-mass redistribution (float64)."""
    n = g.num_vertices
    deg = (g.ptr[1:] - g.ptr[:-1]).astype(np.float64)
    rank = np.full(n, 1.0 / n)
    for _ in range(iters):
        contrib = np.where(deg > 0, rank / np.maximum(deg, 1), 0.0)
        acc = np.zeros(n)
        np.add.at(acc, g.dst, contrib[np.repeat(np.arange(n),
                                                (g.ptr[1:] - g.ptr[:-1]))])
        dangling = rank[deg == 0].sum()
        rank = (1 - damping) / n + damping * (acc + dangling / n)
    return rank


def kcore_ref(g: CSRGraph, k: int) -> np.ndarray:
    """k-core membership by sequential peeling (g symmetric, deduped).

    Repeatedly delete vertices whose remaining degree is < k, decrementing
    each neighbor once per deleted edge.  Returns (V,) int64 in {0, 1}.
    """
    n = g.num_vertices
    deg = (g.ptr[1:] - g.ptr[:-1]).astype(np.int64)
    alive = np.ones(n, bool)
    while True:
        newly = alive & (deg < k)
        if not newly.any():
            break
        alive &= ~newly
        for v in np.flatnonzero(newly):
            for e in range(g.ptr[v], g.ptr[v + 1]):
                deg[g.dst[e]] -= 1
    return alive.astype(np.int64)


def triangles_ref(g: CSRGraph, key: np.ndarray | None = None) -> np.ndarray:
    """Per-vertex triangle counts, each triangle attributed to its
    ``key``-minimum vertex (default: original id order; the engine uses
    placed order, so pass ``pg.place``).  g must be symmetric and deduped;
    ``counts.sum()`` is the total triangle count regardless of ``key``.
    """
    n = g.num_vertices
    key = np.arange(n) if key is None else np.asarray(key)
    adj = [set(g.dst[g.ptr[v]:g.ptr[v + 1]].tolist()) for v in range(n)]
    cnt = np.zeros(n, np.int64)
    for v in range(n):
        for u in adj[v]:
            if key[u] > key[v]:
                for w in adj[u]:
                    if key[w] > key[u] and w in adj[v]:
                        cnt[v] += 1
    return cnt


def spmv_ref(g: CSRGraph, x: np.ndarray) -> np.ndarray:
    """Push-mode SpMV: y[dst] += val * x[src]  (i.e. y = A^T x for CSR-by-src).

    The Dalorex engine propagates along out-edges, so this is the natural
    orientation; callers wanting A x should build the transposed CSR.
    """
    n = g.num_vertices
    src = np.repeat(np.arange(n), (g.ptr[1:] - g.ptr[:-1]))
    y = np.zeros(n, np.float64)
    np.add.at(y, g.dst, g.val.astype(np.float64) * x[src])
    return y
