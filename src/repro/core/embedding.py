"""DalorexEmbedding: vocab-routed, data-local embedding lookup.

The paper's placement + routing applied to LM embedding tables: the table is
scattered across the ``model`` axis by **low-order bits of the vocab id**
(``owner(v) = v mod M``, ``local(v) = v div M`` — the exact arithmetic of
Section III-A), and token ids are *routed to the data* with one all_to_all;
gathered rows ride one all_to_all back.  Compare: the naive sharded lookup
all-gathers a ``V x d`` table (nemotron: 256k x 6144 x 2B = 3.1 GB) per step;
the routed lookup moves ``4·tokens`` bytes of ids + ``2·tokens·d`` bytes of
rows — independent of V.

Overflow semantics follow the paper's channel queues: per-destination slots
are a static ``capacity``; tokens that do not fit get a zero row and are
*counted* (telemetry).  With low-order placement and natural token streams
the per-shard load is near-uniform, so the default slack never overflows in
our tests — the capacity-sweep test exercises the counter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import shard_map_compat
from repro.core.queues import occurrence_index
from repro.parallel.sharding import current_mesh, current_rules


def padded_vocab(vocab: int, shards: int) -> int:
    return ((vocab + shards - 1) // shards) * shards


def place_table(table_rows, num_shards: int):
    """Host helper: (V_pad, d) vocab-order -> placed order (chunked by owner).

    placed[(v % M) * chunk + v // M] = rows[v]."""
    import numpy as np
    v_pad = table_rows.shape[0]
    chunk = v_pad // num_shards
    ids = np.arange(v_pad)
    place = (ids % num_shards) * chunk + ids // num_shards
    out = np.empty_like(table_rows)
    out[place] = table_rows
    return out


def _routed_lookup_local(table_shard, ids, capacity: int, axis: str, M: int):
    """Per-device body (inside shard_map).  table_shard: (V_pad/M, d);
    ids: (n,) int32 local token ids.  Returns (emb (n, d), overflow count).
    """
    n = ids.shape[0]
    owner = ids % M                      # low-order placement = the route
    local_row = ids // M
    valid = ids >= 0
    occ = occurrence_index(owner, valid, M)
    fits = valid & (occ < capacity)
    slot = jnp.where(fits, owner * capacity + occ, M * capacity)
    # send buffer of local row indices; -1 marks empty (headerless validity)
    send = jnp.full((M * capacity + 1,), -1, jnp.int32).at[slot].set(local_row)
    send = send[:-1]
    got = jax.lax.all_to_all(send, axis, 0, 0, tiled=True)  # (M*capacity,)
    rvalid = got >= 0
    rows = jnp.take(table_shard, jnp.maximum(got, 0), axis=0)
    rows = jnp.where(rvalid[:, None], rows, 0)
    back = jax.lax.all_to_all(rows, axis, 0, 0, tiled=True)  # (M*cap, d)
    # result for slot owner*cap+occ returns to the same position (a2a is an
    # involution on the tiled block layout)
    emb = jnp.take(back, jnp.minimum(slot, M * capacity - 1), axis=0)
    emb = jnp.where(fits[:, None], emb, 0)
    overflow = (valid & ~fits).sum(dtype=jnp.int32)
    return emb, overflow


def routed_embed(table, ids, *, model_axis: str = "model",
                 batch_axes=("data",), seq_shard: bool = True,
                 capacity_factor: float = 2.0):
    """Routed lookup as a shard_map island inside a jit region.

    table: (V_pad, d) in *placed* layout, sharded P(model_axis, None).
    ids:   (B, S) int32, sharded P(batch_axes, model_axis if seq_shard).
    Returns (emb (B, S, d) with the same sharding as ids + trailing d,
    overflow scalar).
    """
    mesh = current_mesh()
    if mesh is None:  # single-device path: plain placed-order gather
        M = 1
        emb = jnp.take(table, ids, axis=0)
        return emb, jnp.zeros((), jnp.int32)
    M = mesh.shape[model_axis]
    B, S = ids.shape
    # drop non-divisible shardings (e.g. batch=1 long-context decode)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if B % dp != 0 or B < dp:
        batch_axes, dp = (), 1
    if S % M != 0 or S < M:
        seq_shard = False
    bspec = (tuple(batch_axes) if len(batch_axes) > 1
             else batch_axes[0] if batch_axes else None)
    sspec = model_axis if seq_shard else None
    n_local = (B // dp) * (S // (M if seq_shard else 1))
    capacity = max(1, int(n_local * capacity_factor) // M)

    def body(table_shard, ids_blk):
        flat = ids_blk.reshape(-1)
        emb, ovf = _routed_lookup_local(table_shard, flat, capacity,
                                        model_axis, M)
        emb = emb.reshape(ids_blk.shape + (table_shard.shape[1],))
        return emb, jax.lax.psum(ovf, model_axis)

    out_emb_spec = P(bspec, sspec, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(model_axis, None), P(bspec, sspec)),
        out_specs=(out_emb_spec, P()))
    return fn(table, ids)


def embed_lookup(table, ids, routed: bool, **kw):
    """Entry point used by the models: routed (Dalorex) or replicated."""
    if routed:
        return routed_embed(table, ids, **kw)
    emb = jnp.take(table, ids, axis=0)
    return emb, jnp.zeros((), jnp.int32)
