"""Fixed-capacity, jit-compatible FIFO queues.

These are the software analogue of Dalorex's circular FIFO task queues,
which the paper implements inside each tile's scratchpad (Section III-E).
A queue is a pytree ``(data, count)`` where ``data`` is a ``(cap, width)``
array whose first ``count`` rows are valid, stored in FIFO order and always
compacted to the front. Every operation is static-shape (jit/scan/while_loop
safe) and costs O(cap log cap) for the order-preserving compactions.

All queues store int32; float payloads are bitcast via :func:`f2i`/:func:`i2f`
so a single dtype flows through the network buffers — mirroring the paper's
32-bit flits ("A 32-bit Dalorex can process graphs of up to 2^32 edges").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.mem import alloc


class Queue(NamedTuple):
    data: jax.Array  # (cap, width) int32
    count: jax.Array  # () int32


def f2i(x: jax.Array) -> jax.Array:
    """Bitcast float32 -> int32 (a 32-bit flit)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)


def i2f(x: jax.Array) -> jax.Array:
    """Bitcast int32 -> float32."""
    return jax.lax.bitcast_convert_type(x, jnp.float32)


def queue_make(cap: int, width: int, space: str = "vmem",
               label: str = "queue") -> Queue:
    """Allocate a queue in its declared memory space (``repro.mem``) —
    the registry rejects spaces that cannot hold queue buffers (HBM holds
    only bulk edge shards) at config time, naming ``label``."""
    data = alloc(space, "queue", (cap, width), jnp.int32, label=label)
    return Queue(data, jnp.zeros((), jnp.int32))


def queue_free(q: Queue) -> jax.Array:
    return q.data.shape[0] - q.count


def queue_clear(q: Queue) -> Queue:
    """An emptied queue of the same shape (and zeroed storage, so cleared
    queues compare bit-equal to freshly made ones).  Used by the serving
    front end's lane recycling: a finished query's channel queues are
    reset in place for the next admitted query, without reallocating."""
    return Queue(jnp.zeros_like(q.data), jnp.zeros_like(q.count))


def queue_push(q: Queue, rows: jax.Array, mask: jax.Array) -> tuple[Queue, jax.Array]:
    """Append ``rows[mask]`` (preserving row order) to the queue tail.

    Rows that would exceed capacity are dropped and counted — callers are
    expected to have reserved space (credit/budget) so that ``dropped == 0``;
    the counter exists so tests and production monitors can assert the
    backpressure invariant, like the paper's "CQ full" check.

    Returns (new_queue, n_dropped).
    """
    cap = q.data.shape[0]
    mask = mask.astype(jnp.int32)
    offs = q.count + jnp.cumsum(mask) - mask  # target slot for each masked row
    ok = (mask == 1) & (offs < cap)
    # Scatter into an extended buffer; row `cap` is the trash slot.
    idx = jnp.where(ok, offs, cap)
    ext = jnp.concatenate([q.data, jnp.zeros((1, q.data.shape[1]), jnp.int32)], 0)
    ext = ext.at[idx].set(rows)
    n_push = ok.sum(dtype=jnp.int32)
    n_drop = mask.sum(dtype=jnp.int32) - n_push
    return Queue(ext[:cap], q.count + n_push), n_drop


def queue_take(q: Queue, take_mask: jax.Array) -> tuple[jax.Array, jax.Array, Queue]:
    """Remove entries selected by ``take_mask`` (bool over all cap slots).

    Only slots < count participate. Returns ``(taken_rows, taken_valid, q')``
    where taken rows are compacted to the front of a (cap, width) buffer in
    FIFO order and the remaining queue is re-compacted, order preserved.
    """
    cap = q.data.shape[0]
    ar = jnp.arange(cap, dtype=jnp.int32)
    valid = ar < q.count
    take = take_mask & valid
    keep = valid & ~take
    big = jnp.int32(cap)
    # Unique keys -> deterministic order-preserving partition.
    perm_t = jnp.argsort(jnp.where(take, ar, big + ar))
    n_t = take.sum(dtype=jnp.int32)
    taken = q.data[perm_t]
    taken_valid = ar < n_t
    perm_k = jnp.argsort(jnp.where(keep, ar, big + ar))
    kept = q.data[perm_k]
    n_k = keep.sum(dtype=jnp.int32)
    return taken, taken_valid, Queue(kept, n_k)


def queue_take_front(q: Queue, n: jax.Array, max_n: int) -> tuple[jax.Array, jax.Array, Queue]:
    """Pop the first ``min(n, count)`` entries (FIFO). ``max_n`` is the static
    bound on n; the returned buffer has shape (max_n, width)."""
    cap = q.data.shape[0]
    ar = jnp.arange(cap, dtype=jnp.int32)
    n = jnp.minimum(n, q.count).astype(jnp.int32)
    taken_full, tv_full, q2 = queue_take(q, ar < n)
    return taken_full[:max_n], tv_full[:max_n], q2


def occurrence_index(dest: jax.Array, valid: jax.Array, num_dest: int) -> jax.Array:
    """For each valid element, its 0-based occurrence rank among earlier valid
    elements with the same ``dest``. Invalid elements get rank >= cap.

    This is the vectorized equivalent of the paper's per-channel slot
    assignment: element i may claim slot ``occ[i]`` of channel ``dest[i]``.
    """
    cap = dest.shape[0]
    ar = jnp.arange(cap, dtype=jnp.int32)
    d = jnp.where(valid, dest, num_dest)  # invalid -> trash group
    order = jnp.argsort(d * jnp.int32(cap) + ar)  # unique keys: group, then FIFO
    ds = d[order]
    new_grp = jnp.concatenate([jnp.ones((1,), bool), ds[1:] != ds[:-1]])
    grp_start = jax.lax.associative_scan(jnp.maximum, jnp.where(new_grp, ar, 0))
    occ_sorted = ar - grp_start
    occ = jnp.zeros((cap,), jnp.int32).at[order].set(occ_sorted)
    return jnp.where(valid, occ, jnp.int32(cap))


def histogram(dest: jax.Array, valid: jax.Array, num_dest: int) -> jax.Array:
    """Per-destination counts of valid elements."""
    return jnp.zeros((num_dest,), jnp.int32).at[
        jnp.where(valid, dest, num_dest - 1)
    ].add(valid.astype(jnp.int32))
