"""The Dalorex execution engine: data-local task-flow over a device grid.

The engine executes a :class:`repro.core.program.Program` — an ordered chain
of task channels (the paper's task-based programming model, Section II) —
one *round* at a time (the vectorized analogue of a window of machine
cycles).  Per round, every device runs:

  source   pop local frontier bits -> channel-0 tasks (the paper's T4/T1
           head: one (edge_start, edge_end, payload) task per vertex)
  per channel, in program order (one generic leg each):
           queue -> TSU budget -> transform (e.g. the T1 range split at
           chunk borders and MAX_T2, Listing 1)
           --- route by owner(head flit) over the NoC backend ---
           handler at the owner tile (edge scan, fold, ...) -> successor
           messages for the next channel; spills -> local queue.

The classic workloads (BFS, SSSP, PageRank, WCC, SpMV) compile to the
3-task program T1 range split -> T2 edge scan -> T3 fold
(:func:`repro.core.program.classic_program`); k-core peeling swaps the
fold; triangle counting runs a 4-channel chain.  The engine itself is
workload-agnostic: it only iterates channels.

The fabric between channels is a pluggable :mod:`repro.noc` Network
selected by ``EngineConfig.noc``: the ideal crossbar, a physical mesh /
torus / ruche grid, or the multi-die ``hier`` composition (an
``ndies_y x ndies_x`` array of intra-die grids joined by DIE-class express
links), all with dimension-ordered routing, per-link capacities, and
per-link telemetry (``Stats.flits_per_link``, ``Stats.die_crossings``
etc.).

Backpressure: routing capacity is finite (endpoint slots *and*, for the
physical NoCs, per-link flits); overflow *spills* back into the channel's
local queue — of whichever tile the message is stranded at, since routes
are re-derived from the head flit — and is replayed next round, the
software form of the paper's "CQ full -> early exit, resume next
invocation".  Nothing is ever dropped; tests assert ``drops == 0``.

Scheduling: per-round budgets are chosen per device by a generic arbiter
over the N channel queue occupancies plus the NoC's fed-back link occupancy
— the Task Scheduling Unit's traffic-aware priorities (Section III-E),
adapted from per-cycle arbitration to per-round budget allocation.  The
drain-consumers-first / throttle-producers ordering falls out of the
channel DAG: the deepest consumer always drains in full, and a channel's
budget is quartered while any *downstream* queue (or the fabric) is
congested; the frontier source stops entirely.  ``policy="static"``
reproduces the paper's round-robin arbitration rung of the Fig. 5 ablation.

Synchronization: ``mode="async"`` is barrierless Dalorex — vertices
re-armed by a fold re-enter the *live* frontier immediately.  ``mode="bsp"``
defers them to a next-epoch frontier swapped in only when the whole grid is
quiescent (the paper's per-epoch global barrier, driven by the same idle
signal).

Termination is the paper's hierarchical idle wire: a psum of local pending
work (queue occupancies + frontier population); the loop exits when it hits
zero.  The whole traversal runs inside ONE ``lax.while_loop`` — on real
meshes there is no host round-trip per round.

Each round is also *priced* by the :mod:`repro.perf` cost model
(``EngineConfig.perf``): the slowest tile's compute plus the busiest
link's serialization accumulate into ``Stats.cycles``, and the round's
counters into ``Stats.energy_pj`` — so benchmarks report modeled time /
GTEPS / joules, not just rounds (DESIGN.md "Performance model").

The per-tile legs themselves execute on ``EngineConfig.backend``: "xla"
traces them inline, "pallas" dispatches to the tile-grid kernels of
:mod:`repro.kernels.engine` (one grid program = one tile, shard resident
in VMEM) — bit-identical by contract, per-channel overridable via
``TaskSpec.backend`` (DESIGN.md "Pallas backend").

Everything here is single-query; the serving subsystem
(:mod:`repro.serve`) vmaps the round built by :func:`make_round` over a
leading *query-lane* axis so a batch of B traversals shares the resident
graph, the rounds and the fabric, freezing each lane with
:func:`lane_select` when its own :func:`pending_work` signal hits zero
(DESIGN.md "Query serving").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import AxisComm, LocalComm
from repro.core.graph import PartitionedGraph
from repro.core.program import (BFS, PAGERANK, SPMV, SSSP,  # noqa: F401
                                WCC, AlgSpec, Ctx, INF, Program, TaskSpec,
                                as_program, resolve_edge_space)
from repro.core.queues import (Queue, f2i, i2f, queue_make, queue_push,
                               queue_take_front)
from repro.kernels.engine import (fifo_turn, fused_leg_call, queue_append,
                                  queue_push_pop, tally)
from repro.mem import resolve_window
from repro.noc import make_network
from repro.noc.topology import N_LINK_CLASSES
from repro.perf import (PerfParams, link_cost_vectors, round_energy_pj,
                        tile_compute_cycles)
from repro.trace.buffer import record_round, zero_trace


# --------------------------------------------------------------------------
# Engine configuration and state.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static knobs.  Sizes are per device; all shapes they imply are static.

    The queue/budget names mirror the paper:  ``cap_route_*`` are the channel
    queue (CQ) capacities *per destination*, ``max_t2`` is Listing 1's MAX_T2
    (edge-scan length bound per message), the ``*_pop`` budgets are the TSU's
    per-invocation drain amounts.  These are the *defaults* for a Program's
    channels, selected by each TaskSpec's ``knobs`` tag ("range" /
    "update"); a TaskSpec can override them per channel.
    """

    f_pop: int = 32          # frontier bits popped per round (T4 drain)
    r_pop: int = 32          # "range"-knob queue entries popped per round
    u_pop: int = 64          # "update"-knob spilled entries replayed
    max_t2: int = 32         # edge-scan bound per range message (MAX_T2)
    cap_route_range: int = 16    # CQ slots per destination, "range" channels
    cap_route_update: int = 64   # CQ slots per destination, "update" channels
    cap_rangeq: int = 2048   # local task-queue capacity, "range" channels
    cap_updq: int = 16384    # local spill-queue capacity, "update" channels
    policy: str = "traffic"  # "traffic" | "static"
    mode: str = "async"      # "async" (barrierless) | "bsp"
    max_rounds: int = 100_000
    # --- execution backend of the per-tile round legs ---
    # "xla" traces the queue/scan/fold legs inline; "pallas" dispatches them
    # to the repro.kernels.engine tile-grid kernels (one grid program = one
    # tile, shard resident in VMEM).  Results are bit-identical by contract
    # (tests/test_backend_pallas.py).  A TaskSpec.backend hint overrides
    # this per channel.  ``pallas_interpret=True`` (the default) runs the
    # kernels through the Pallas interpreter so CPU CI executes the same
    # kernel bodies; set False only on a real TPU (DESIGN.md caveats).
    backend: str = "xla"     # "xla" | "pallas"
    pallas_interpret: bool = True
    # ``pallas_fuse=True`` (the default) runs each channel leg whose
    # channels all resolved to "pallas" as ONE pallas_call — the whole
    # per-tile stage (frontier pop, FIFO turn, spill re-queue, remainder
    # re-push, scan, fold) becomes the kernel body with VMEM-resident
    # intermediates (repro.kernels.engine.fused_leg_call).  False keeps
    # the legacy one-kernel-per-building-block dispatch (4+ launches per
    # leg plus XLA glue); both are bit-identical to "xla".
    # ``pallas_pad_lanes`` pads every fused-leg operand block to the TPU's
    # (8, 128) sublane x lane f32 tile (sliced back inside the body) so
    # the non-interpret path lands aligned blocks; value-neutral.
    # ``Stats.launches`` counts the pallas_call dispatches per round.
    pallas_fuse: bool = True
    pallas_pad_lanes: bool = False
    # --- memory spaces (repro.mem) ---
    # ``edge_space`` declares where the tile's edge shard lives: "vmem"
    # (word-random resident, the default) or "hbm" (the shard streams
    # through double-buffered segment-DMA windows of ``hbm_window``
    # elements; 0 auto-sizes to the next pow2 >= max_t2).  Programs may
    # pin their own shard space (e.g. triangles pins "vmem"); see
    # program.resolve_edge_space.  ``vmem_limit_bytes`` overrides the
    # registry's per-tile VMEM capacity for Program.validate's
    # config-time footprint check (0 = the registry default) — the knob
    # that models a smaller tile, and the error that replaced the old
    # implicit "everything fits in VMEM" assumption.
    edge_space: str = "vmem"
    hbm_window: int = 0
    vmem_limit_bytes: int = 0
    # --- NoC backend (repro.noc) ---
    noc: str = "ideal"       # "ideal" | "mesh" | "torus" | "ruche" | "hier"
    noc_rows: int = 0        # grid rows; 0 = near-square factorization of T
    link_cap: int = 0        # flits per directed link per routing leg (a
                             # round has one leg per channel); 0 = off
    ruche_factor: int = 2    # tiles skipped by a ruche channel (noc="ruche")
    # hier (die-of-dies) geometry: the grid is cut into ndies_y x ndies_x
    # equal dies wired internally as hier_base ("mesh" | "torus") and
    # joined by DIE-class express links; ndies_x = ndies_y = 1 with a mesh
    # base is bit-identical to noc="mesh" (tests/test_hier.py)
    ndies_x: int = 1         # die columns (noc="hier")
    ndies_y: int = 1         # die rows (noc="hier")
    hier_base: str = "mesh"  # intra-die wiring (noc="hier")
    # --- cycle/energy cost model (repro.perf) ---
    perf: PerfParams = PerfParams()
    # --- flight recorder (repro.trace) ---
    # ``trace=True`` carries a TraceBuf ring through the round loop,
    # recording per-round series (per-channel msgs/spills/queue depth,
    # per-tile busy cycles + critical-path tile, per-link-class flits,
    # TSU budget grants, HBM windows, frontier/pending) every
    # ``trace_every``-th round into a bounded ``trace_rounds``-slot ring
    # (oldest rounds overwritten).  Contract: trace=False is
    # byte-identical to a build without the recorder; trace=True never
    # perturbs values or Stats (tests/test_trace.py).
    trace: bool = False
    trace_every: int = 1
    trace_rounds: int = 512
    # --- telemetry-driven adaptive placement (repro.place) ---
    # ``adapt=True`` lets the epoch-boundary repartitioner run: between
    # engine epochs (host-driven, e.g. PageRank's) or between serving
    # queries, a migration plan derived from the flight recorder's
    # per-tile busy series / the partition's die-affinity is applied as a
    # pure relabeling (repro.place.apply_plan).  ``adapt_every`` is the
    # epoch/batch cadence; ``adapt_budget`` caps migrated vertices per
    # adaptation.  The engine round loop itself never migrates — plans
    # apply only at quiescent boundaries, so converged values stay
    # bit-identical to the unmigrated run (tests/test_place.py).
    adapt: bool = False
    adapt_every: int = 1
    adapt_budget: int = 64

    def min_caps(self, T: int) -> tuple[int, int]:
        """Worst-case per-round queue inflow for the *classic* program
        shape: (rangeq_need, updq_need).  The generic, per-channel version
        is :meth:`repro.core.program.Program.min_caps`; this closed form is
        kept because benchmarks size their queues from it."""
        burst = T * self.cap_route_range * self.max_t2 + self.u_pop
        rangeq_need = 2 * self.f_pop
        if self.noc != "ideal":
            burst += T * self.cap_route_update
            rangeq_need += 2 * self.r_pop + T * self.cap_route_range
        return rangeq_need, burst

    def validate(self, T: int):
        # queues must absorb a full worst-case burst so the no-drop
        # invariant holds even under static scheduling.
        rangeq_need, burst = self.min_caps(T)
        assert self.cap_updq >= burst, (
            f"cap_updq={self.cap_updq} < worst-case T2 burst {burst}")
        assert self.cap_rangeq >= rangeq_need, (
            f"cap_rangeq={self.cap_rangeq} < worst-case inflow {rangeq_need}")


class EngineState(NamedTuple):
    value: jax.Array      # (v_chunk,) f32 — dist / label / rank / x / degree
    acc: jax.Array        # (v_chunk,) f32 — accumulator / removed flag
    frontier: jax.Array   # (v_chunk,) bool — local bitmap frontier (live)
    next_frontier: jax.Array  # (v_chunk,) bool — BSP-deferred frontier
    queues: tuple         # one Queue per program channel
    net_pressure: jax.Array  # () i32 — last round's occupancy on own links


class Stats(NamedTuple):
    rounds: jax.Array
    epochs: jax.Array           # BSP frontier swaps (0 in async mode)
    msgs: jax.Array             # (K,) messages delivered per task channel
    spills: jax.Array           # (K,) spill-and-replay events per channel
    edges_scanned: jax.Array    # work of "edges"-tagged handlers (scans)
    updates_applied: jax.Array  # work of "updates"-tagged handlers (folds)
    drops: jax.Array            # MUST be 0 — backpressure invariant
    work_max: jax.Array         # max per-device edges_scanned (balance)
    # --- NoC telemetry (shapes fixed by the Network backend) ---
    flits_per_link: jax.Array       # (num_links,) cumulative flit traversals
    max_link_occupancy: jax.Array   # () peak per-round per-link occupancy
    hop_histogram: jax.Array        # (max_hops+1,) injections by hop count
    die_crossings: jax.Array        # (max_die_crossings+1,) injections by
                                    # die boundaries crossed (bin 0 only,
                                    # on single-die fabrics)
    # --- cycle/energy model (repro.perf; f32 — magnitudes exceed int32,
    # and the in-loop accumulation is Kahan-compensated so small per-round
    # increments survive far past f32's 2^24 integer ceiling) ---
    cycles: jax.Array               # () modeled cycles, per-round critical
                                    # path summed over rounds
    energy_pj: jax.Array            # () modeled energy, linear in counters
    # --- launch accounting (repro.kernels.engine.launches) ---
    launches: jax.Array             # () pallas_call dispatches, summed over
                                    # rounds (0 on the xla backend; counted
                                    # at trace time, identical across comm
                                    # backends — intentionally NOT part of
                                    # the cross-backend equivalence
                                    # contract)
    # --- per-space traffic (repro.mem; 0 unless the edge shard resolved
    # to "hbm" — stats_row omits the columns when zero, the same additive
    # convention as ``launches``, so pre-memspace baseline rows stay
    # byte-stable.  NOT part of the vmem-vs-hbm space-equivalence
    # contract, by design: they are what *differs* between spaces) ---
    hbm_windows: jax.Array          # () DMA windows fetched (2 per
                                    # delivered range message: the double
                                    # buffer)
    hbm_edges: jax.Array            # () edge words streamed from HBM
                                    # (windows * window size), priced at
                                    # t_hbm / e_hbm
    # --- adaptive-placement migration accounting (repro.place; 0 unless
    # a migration plan was applied between epochs/queries — stats_row
    # omits the columns when zero, the same additive convention as
    # ``launches``, so pre-adaptive baseline rows stay byte-stable.
    # Added host-side by repro.place.price_migration at the quiescent
    # boundary the plan applied at; the in-loop round accumulator only
    # carries them through) ---
    migrated_vertices: jax.Array    # () vertices moved by applied plans
    migration_cycles: jax.Array     # () modeled cycles of the moves (also
                                    # folded into ``cycles``)
    migration_pj: jax.Array         # () modeled energy of the moves (also
                                    # folded into ``energy_pj``; kept so
                                    # energy_from_totals reconciles)

    # Legacy scalar views: the classic program's two channels.
    @property
    def msgs_range(self):
        return self.msgs[..., 0]

    @property
    def msgs_update(self):
        return self.msgs[..., -1]

    @property
    def spills_range(self):
        return self.spills[..., 0]

    @property
    def spills_update(self):
        return self.spills[..., -1]

    @staticmethod
    def zero(num_links: int = 1, max_hops: int = 1, num_channels: int = 2,
             max_die_crossings: int = 0):
        z = jnp.zeros((), jnp.int32)
        zf = jnp.zeros((), jnp.float32)
        return Stats(z, z,
                     jnp.zeros((num_channels,), jnp.int32),
                     jnp.zeros((num_channels,), jnp.int32),
                     z, z, z, z,
                     jnp.zeros((num_links,), jnp.int32), z,
                     jnp.zeros((max_hops + 1,), jnp.int32),
                     jnp.zeros((max_die_crossings + 1,), jnp.int32),
                     zf, zf, z, z, z, z, zf, zf)


def zero_stats(cfg: EngineConfig, T: int, alg=BFS) -> Stats:
    """A Stats zero whose telemetry shapes match the NoC backend ``cfg``
    selects and whose channel counters match the program — safe to
    accumulate with real runs (the ``Stats.zero()`` defaults are not)."""
    prog = as_program(alg)
    net = make_network(cfg, T)
    return Stats.zero(net.num_links, net.max_hops, len(prog.channels),
                      net.max_die_crossings)


class GraphShard(NamedTuple):
    """One device's chunk of the four dataset arrays (placed space)."""
    ptr_start: jax.Array  # (v_chunk,) i32 global placed edge index
    deg: jax.Array        # (v_chunk,) i32
    edge_dst: jax.Array   # (e_chunk,) i32 placed dst (-1 pad)
    edge_val: jax.Array   # (e_chunk,) f32


# --------------------------------------------------------------------------
# The TSU: a generic arbiter over N channel occupancies + fabric pressure.
# --------------------------------------------------------------------------

def _budgets(cfg: EngineConfig, prog: Program, qcaps, pops, st: EngineState,
             plimit: int):
    """Per-round budgets from the channel queue occupancies AND the NoC's
    per-link occupancy fed back from last round (Section III-E).

    Priorities derive from the program DAG: the deepest consumer always
    drains (its IQ filling up is the main source of endpoint contention);
    a producer channel is throttled to 1/4 budget while any *downstream*
    queue is congested (> 3/4 full) or the fabric is hot; the frontier
    source stops entirely while channel 0 is half full or anything
    downstream is congested.  Returns (source_budget, (K,) channel pops).
    """
    K = len(prog.channels)
    occ = [st.queues[i].count for i in range(K)]
    free0 = jnp.int32(qcaps[0]) - occ[0]
    if cfg.policy == "static":
        f_pop = jnp.minimum(jnp.int32(cfg.f_pop), jnp.maximum(free0, 0))
        return f_pop, jnp.stack([jnp.int32(p) for p in pops])
    net_hot = st.net_pressure > jnp.int32(max(plimit, 1))
    congested = [occ[i] > (3 * qcaps[i]) // 4 for i in range(K)]
    chan_pops = [None] * K
    down = jnp.zeros((), bool)          # any congested queue downstream
    for i in reversed(range(K)):
        if i == K - 1:
            chan_pops[i] = jnp.int32(pops[i])
        else:
            # classic 2-channel shape: quarter the producer (the paper's
            # throttle rung).  Deeper chains amplify (each channel fans out
            # again), so a quartered producer can still outrun the last
            # channel's drain — stop producers outright until the backlog
            # clears; the last channel always drains, so this cannot
            # deadlock.
            throttled = pops[i] // 4 if K == 2 else 0
            chan_pops[i] = jnp.where(down | net_hot,
                                     jnp.int32(throttled),
                                     jnp.int32(pops[i]))
        down = down | congested[i]
    down_of_source = net_hot
    for i in range(1, K):
        down_of_source = down_of_source | congested[i]
    half0 = occ[0] > qcaps[0] // 2
    f_pop = jnp.where(
        half0 | down_of_source, jnp.int32(0),
        jnp.minimum(jnp.int32(cfg.f_pop),
                    jnp.maximum(free0 - 2 * cfg.f_pop, 0)))
    return f_pop, jnp.stack(chan_pops)


def pending_work(me, st: EngineState):
    """Per-device pending work (frontier population + queue occupancies) —
    the local contribution to the paper's hierarchical idle wire.  Public
    because the serving lane runner (:mod:`repro.serve.lanes`) computes a
    *per-query* idle signal from the same definition."""
    p = st.frontier.sum(dtype=jnp.int32)
    for q in st.queues:
        p = p + q.count
    return p


_pending = pending_work


def lane_select(active: jax.Array, old, new):
    """Per-lane masked select over matching lane-led pytrees.

    ``active`` is a ``(B,)`` bool vector; every leaf of ``old``/``new`` is
    lane-led ``(B, ...)``.  Returns ``new`` where the lane is active and
    ``old`` where it is frozen — the query-lane analogue of BSP's
    do-nothing round: a finished query's state, Stats and Kahan
    compensation stop evolving the round its pending work hits zero, which
    is what keeps each lane's trajectory bit-identical to a solo run
    (tests/test_serve.py).
    """
    def sel(o, n):
        m = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, old, new)


def _next_pending(me, st: EngineState):
    return st.next_frontier.sum(dtype=jnp.int32)


def _bsp_swap(me, st: EngineState, do_swap):
    frontier = jnp.where(do_swap, st.frontier | st.next_frontier, st.frontier)
    nxt = jnp.where(do_swap, jnp.zeros_like(st.next_frontier),
                    st.next_frontier)
    return st._replace(frontier=frontier, next_frontier=nxt)


def _set_queue(st: EngineState, i: int, q: Queue) -> EngineState:
    return st._replace(queues=st.queues[:i] + (q,) + st.queues[i + 1:])


# --------------------------------------------------------------------------
# The generic round + driver, parametric over the comm backend.
# --------------------------------------------------------------------------

def make_round(comm, net, cfg: EngineConfig, prog: Program, e_chunk: int,
               v_chunk: int, shard: GraphShard):
    """Build the per-round function ``(state, stats, kahan_comp, tbuf) ->
    (state, stats, kahan_comp, tbuf, pending)`` where ``kahan_comp`` is
    the ``(cycles, energy)`` f32 compensation pair of the perf model's
    in-loop summation (threaded through the ``while_loop`` carry, never
    surfaced) and ``tbuf`` is the flight recorder's ring
    (:mod:`repro.trace`) when ``cfg.trace`` — an empty pytree ``()``
    otherwise, so the trace-off carry is byte-identical to a build
    without the recorder.

    One generic ``queue -> budget -> transform -> net.route -> handler ->
    spill`` leg per program channel, with the destination decoded from the
    head flit (the paper's headerless routing).  ``net`` is a
    :mod:`repro.noc` Network backend; every leg goes through it.

    Each leg executes on the backend resolved from ``cfg.backend`` and the
    channel's ``TaskSpec.backend`` hint: "xla" inline, or "pallas" via the
    :mod:`repro.kernels.engine` tile-grid kernels (the fused queue turn
    here; the scan/fold kernels inside the dispatching handlers).  The TSU,
    the NoC, and the perf model are backend-agnostic — they only ever see
    the legs' (bit-identical) outputs.
    """
    # Memory space of the edge shard (repro.mem): "hbm" switches the T2
    # building blocks to the double-buffered segment-DMA stream and turns
    # on per-space traffic accounting below.
    edge_space = resolve_edge_space(prog, cfg)
    window = resolve_window(cfg.hbm_window, cfg.max_t2) \
        if edge_space == "hbm" else 0
    ctx = Ctx(cfg, comm.size, e_chunk, v_chunk,
              edge_space=edge_space, hbm_window=window)
    chans = prog.channels
    K = len(chans)
    backends = tuple(ch.resolve_backend(cfg) for ch in chans)
    # Leg fusion (pallas_fuse): legs are indexed 0 (stage_first: channel
    # 0's source + ingest), 1..K-1 (make_mid(i): channel i-1's handler +
    # channel i's ingest) and K (stage_last: channel K-1's handler).  A
    # leg runs as ONE pallas_call iff every channel it spans resolved to
    # "pallas" — a per-channel "xla" pin de-fuses just the legs it touches.
    fuse = cfg.pallas_fuse
    leg_fused = ((fuse and backends[0] == "pallas",)
                 + tuple(fuse and backends[i - 1] == "pallas"
                         and backends[i] == "pallas"
                         for i in range(1, K))
                 + (fuse and backends[K - 1] == "pallas",))

    def leg_ctx(chan_i, leg_i):
        """The Ctx a building block of channel ``chan_i`` sees inside leg
        ``leg_i`` — fused legs route the blocks to the pure kernel bodies
        (no nested pallas_call)."""
        return ctx._replace(backend=backends[chan_i],
                            fused=leg_fused[leg_i])

    def wrap_leg(stage, leg_i):
        """Fused legs: the whole per-tile stage becomes one pallas_call
        body (intermediates VMEM-resident), via fused_leg_call."""
        if not leg_fused[leg_i]:
            return stage

        def fused_stage(me, *args):
            return fused_leg_call(stage, me, *args,
                                  interpret=cfg.pallas_interpret,
                                  pad_lanes=cfg.pallas_pad_lanes)
        return fused_stage

    caps = tuple(ch.route_cap(cfg) for ch in chans)
    pops = tuple(ch.pop_budget(cfg) for ch in chans)
    qcaps = tuple(ch.qcap(cfg) for ch in chans)
    owners = tuple(ch.owner_fn(ctx) for ch in chans)
    plimit = net.pressure_limit(cfg, caps)
    pp = cfg.perf
    t_hop, e_hop = link_cost_vectors(pp, net)
    tracing = cfg.trace
    if tracing:
        # static (C, num_links) one-hot splitting per-link flits by cost
        # class for the recorder's per-class series
        _cls = np.asarray(net.link_classes)
        cls_onehot = jnp.asarray(
            (_cls[None, :] == np.arange(N_LINK_CLASSES)[:, None])
            .astype(np.int32))

    def requeue(st, i, sp, spv, cx):
        """Spill re-queue into channel i's local queue.  Inside a fused leg
        this is the in-kernel :func:`queue_append` body (bit-identical to
        ``queue_push``) — the XLA glue the single launch absorbs."""
        q = st.queues[i]
        if cx.fused:
            qdata, qcount, d = queue_append(q.data, q.count, sp, spv)
            q = Queue(qdata, qcount)
        else:
            q, d = queue_push(q, sp, spv)
        return _set_queue(st, i, q), d

    def ingest(i, st, rows, valid, pop_i, cx):
        """Feed fresh rows into channel i and produce its network messages.

        Queued channels (real task queues) push fresh tasks, pop up to the
        budget, and bound each popped task via the channel transform
        (re-pushing remainders).  Spill-only channels replay their backlog
        ahead of the fresh messages.

        Also returns this tile's queue-op counts for the cycle model:
        ``npop`` entries dequeued and ``npush`` entries enqueued (fresh
        tasks + re-pushed split remainders) this round.

        On the pallas backend the push+pop pair is one fused FIFO turn
        (spill-only channels turn with an empty fresh batch): the
        standalone :func:`repro.kernels.engine.queue_push_pop` kernel when
        the leg is unfused, or the in-kernel :func:`fifo_turn` body when
        the whole leg is already a single pallas_call (``cx.fused``), in
        which case the split-remainder re-push is absorbed in-kernel too
        via :func:`queue_append`.
        """
        q = st.queues[i]
        if chans[i].queued:
            if cx.backend == "pallas":
                turn = fifo_turn if cx.fused else functools.partial(
                    queue_push_pop, interpret=cfg.pallas_interpret)
                taken, tvalid, qdata, qcount, d0 = turn(
                    q.data, q.count, rows, valid, pop_i, pops[i])
                q = Queue(qdata, qcount)
            else:
                q, d0 = queue_push(q, rows, valid)
                taken, tvalid, q = queue_take_front(q, pop_i, pops[i])
            msgs, mvalid, rem, remv = chans[i].transform(cx, taken, tvalid)
            if cx.fused:
                qdata, qcount, d1 = queue_append(q.data, q.count, rem, remv)
                q = Queue(qdata, qcount)
            else:
                q, d1 = queue_push(q, rem, remv)
            drops = d0 + d1
            npop = tvalid.sum(dtype=jnp.int32)
            npush = (valid.sum(dtype=jnp.int32)
                     + remv.sum(dtype=jnp.int32))
        else:
            if cx.backend == "pallas":
                none = jnp.zeros((1,), bool)
                pad = jnp.zeros((1, q.data.shape[1]), jnp.int32)
                turn = fifo_turn if cx.fused else functools.partial(
                    queue_push_pop, interpret=cfg.pallas_interpret)
                replay, rvalid, qdata, qcount, _ = turn(
                    q.data, q.count, pad, none, pop_i, pops[i])
                q = Queue(qdata, qcount)
            else:
                replay, rvalid, q = queue_take_front(q, pop_i, pops[i])
            msgs = jnp.concatenate([replay, rows], axis=0)
            mvalid = jnp.concatenate([rvalid, valid], axis=0)
            drops = jnp.zeros((), jnp.int32)
            npop = rvalid.sum(dtype=jnp.int32)
            npush = jnp.zeros((), jnp.int32)
        return _set_queue(st, i, q), msgs, mvalid, drops, npop, npush

    cx_first = leg_ctx(0, 0)

    def stage_first(me, sh, st):
        f_pop, dyn_pops = _budgets(cfg, prog, qcaps, pops, st, plimit)
        st, rows, valid = prog.source(cx_first, me, sh, st, f_pop)
        st, msgs, mvalid, drops, npop, npush = ingest(
            0, st, rows, valid, dyn_pops[0], cx_first)
        return st, msgs, mvalid, drops, dyn_pops, npop, npush

    stage_first = wrap_leg(stage_first, 0)

    def make_mid(i):
        cx_h = leg_ctx(i - 1, i)  # channel i-1's handler under this leg
        cx_q = leg_ctx(i, i)      # channel i's ingest under this leg

        def stage(me, sh, st, recv, rv, sp, spv, dyn_pops):
            st, d0 = requeue(st, i - 1, sp, spv, cx_h)
            st, rows, valid, work = chans[i - 1].handler(
                cx_h, me, sh, st, recv, rv)
            st, msgs, mvalid, d1, npop, npush = ingest(
                i, st, rows, valid, dyn_pops[i], cx_q)
            nspill = spv.sum(dtype=jnp.int32)
            return st, msgs, mvalid, d0 + d1, work, npop, npush, nspill
        return wrap_leg(stage, i)

    cx_last = leg_ctx(K - 1, K)

    def stage_last(me, sh, st, recv, rv, sp, spv):
        st, d0 = requeue(st, K - 1, sp, spv, cx_last)
        st, _, _, work = chans[K - 1].handler(cx_last, me, sh, st, recv,
                                              rv)
        return st, d0, work, spv.sum(dtype=jnp.int32)

    stage_last = wrap_leg(stage_last, K)

    def kahan_add(total, comp, inc):
        """Compensated f32 accumulation: (new_total, new_comp)."""
        y = inc - comp
        t = total + y
        return t, (t - total) - y

    def rnd(st: EngineState, stats: Stats, kcomp, tbuf=()):
        st0, round_ix = st, stats.rounds  # pre-round views (trace only)
        # The round body is traced exactly once per compile, so the
        # pallas_call dispatches recorded while tracing the stages below
        # ARE this round's launch count (repro.kernels.engine.launches) —
        # a Python int folded into Stats.launches, identical under
        # LocalComm/vmap, shard_map and the serving-lane vmap.
        with tally() as launch_tally:
            st, msgs, mvalid, drops, dyn_pops, n_pop, n_push = comm.run(
                stage_first, shard, st)
            routed = net.route(comm, msgs, mvalid, caps[0], owners[0])
            link_round = routed.link_flits
            hop_round = routed.hop_hist
            die_round = routed.die_hist
            sents = [routed.sent]
            spillv = [routed.spill_valid]
            edges = jnp.zeros_like(drops)
            applied = jnp.zeros_like(drops)
            n_replay = jnp.zeros_like(drops)
            hbm_win = jnp.zeros_like(drops)

            def count_windows(acc, rvalid):
                # Per-tile DMA accounting of the streamed T2: each range
                # message delivered to an "edges" handler fetches its two
                # covering windows (the double buffer) — what the machine
                # transfers, independent of the emulation's vectorized
                # staging.
                return acc + comm.run(
                    lambda me, v: 2 * v.sum(dtype=jnp.int32), rvalid)

            for i in range(1, K):
                if edge_space == "hbm" and chans[i - 1].work == "edges":
                    hbm_win = count_windows(hbm_win, routed.recv_valid)
                st, msgs, mvalid, d, work, npop, npush, nspill = comm.run(
                    make_mid(i), shard, st, routed.recv, routed.recv_valid,
                    routed.spill, routed.spill_valid, dyn_pops)
                drops = drops + d
                n_pop = n_pop + npop
                n_push = n_push + npush
                n_replay = n_replay + nspill
                if chans[i - 1].work == "edges":
                    edges = edges + work
                elif chans[i - 1].work == "updates":
                    applied = applied + work
                routed = net.route(comm, msgs, mvalid, caps[i], owners[i])
                link_round = link_round + routed.link_flits
                hop_round = hop_round + routed.hop_hist
                die_round = die_round + routed.die_hist
                sents.append(routed.sent)
                spillv.append(routed.spill_valid)
            if edge_space == "hbm" and chans[K - 1].work == "edges":
                hbm_win = count_windows(hbm_win, routed.recv_valid)
            st, d, work, nspill = comm.run(stage_last, shard, st,
                                           routed.recv, routed.recv_valid,
                                           routed.spill,
                                           routed.spill_valid)
        drops = drops + d
        n_replay = n_replay + nspill
        if chans[K - 1].work == "edges":
            edges = edges + work
        elif chans[K - 1].work == "updates":
            applied = applied + work

        # NoC telemetry: global per-link occupancy of this round, and the
        # per-tile pressure fed back into next round's TSU budgets.
        link_round = comm.psum(link_round)
        hop_round = comm.psum(hop_round)
        die_round = comm.psum(die_round)
        st = st._replace(net_pressure=comm.run(
            lambda me, lf: net.pressure(me, lf), link_round))

        pending = comm.psum(comm.run(_pending, st))
        nxt = comm.psum(comm.run(_next_pending, st))
        if cfg.mode == "bsp":
            do_swap = (pending == 0) & (nxt > 0)
            st = comm.run(_bsp_swap, st, _bcast(comm, do_swap))
            epochs_inc = do_swap
            pending = pending + nxt
        else:
            epochs_inc = jnp.zeros_like(pending)

        glob = comm.to_global
        msgs_vec = jnp.stack([glob(comm.psum(s)) for s in sents])
        spills_vec = jnp.stack([
            glob(comm.psum(comm.run(
                lambda me, v: v.sum(dtype=jnp.int32), sv)))
            for sv in spillv])
        link_g = glob(link_round)
        edges_g = glob(comm.psum(edges))
        applied_g = glob(comm.psum(applied))

        # Cycle/energy model (repro.perf): the round costs its slowest
        # tile's compute plus the busiest link's serialization, each link
        # priced by its class (local / ruche express / torus wrap).  An
        # HBM-resident shard additionally pays t_hbm/e_hbm per streamed
        # edge word (the per-space pricing split; the terms are absent —
        # not zero-multiplied — on all-VMEM runs, keeping them bit-stable
        # with the pre-memspace model).
        streaming = edge_space == "hbm"
        hbm_edges_tile = hbm_win * jnp.int32(window) if streaming else None
        hw_g = glob(comm.psum(hbm_win))
        he_g = hw_g * jnp.int32(window) if streaming else hw_g
        comp = tile_compute_cycles(pp, n_pop, n_push, n_replay, edges,
                                   applied, hbm_edges=hbm_edges_tile)
        cyc_round = (jnp.float32(pp.t_round) + glob(comm.pmax(comp))
                     + (link_g.astype(jnp.float32) * t_hop).max())
        energy_round = round_energy_pj(
            pp, comm.size, edges_g, applied_g, msgs_vec.sum(),
            spills_vec.sum(), link_g, e_hop, cyc_round,
            hbm_edges_g=he_g if streaming else None)
        cycles_acc, c_cyc = kahan_add(stats.cycles, kcomp[0], cyc_round)
        energy_acc, c_en = kahan_add(stats.energy_pj, kcomp[1],
                                     energy_round)

        stats = Stats(
            rounds=stats.rounds + 1,
            epochs=stats.epochs + glob(epochs_inc),
            msgs=stats.msgs + msgs_vec,
            spills=stats.spills + spills_vec,
            edges_scanned=stats.edges_scanned + edges_g,
            updates_applied=stats.updates_applied + applied_g,
            drops=stats.drops + glob(comm.psum(drops)),
            work_max=stats.work_max + glob(comm.pmax(edges)),
            flits_per_link=stats.flits_per_link + link_g,
            max_link_occupancy=jnp.maximum(stats.max_link_occupancy,
                                           link_g.max()),
            hop_histogram=stats.hop_histogram + glob(hop_round),
            die_crossings=stats.die_crossings + glob(die_round),
            cycles=cycles_acc,
            energy_pj=energy_acc,
            launches=stats.launches + jnp.int32(launch_tally.n),
            hbm_windows=stats.hbm_windows + hw_g,
            hbm_edges=stats.hbm_edges + he_g,
            migrated_vertices=stats.migrated_vertices,
            migration_cycles=stats.migration_cycles,
            migration_pj=stats.migration_pj,
        )
        if tracing:
            # Flight recorder (repro.trace): pure reads of telemetry the
            # round already computed, plus trace-only reductions — nothing
            # here feeds back into state, values or Stats (the invariance
            # contract).  All recorded values are global/replicated, like
            # Stats, so shard_map carries an identical ring per device.
            comp_all = comm.to_global(comm.all_gather(comp))  # (T,) f32
            occ = comm.run(
                lambda me, s: jnp.stack([q.count for q in s.queues]), st)
            # the TSU's source grant, recomputed from the same pre-round
            # state stage_first arbitrated on (same integer math)
            src_grant = comm.run(
                lambda me, s: _budgets(cfg, prog, qcaps, pops, s,
                                       plimit)[0], st0)
            tbuf = record_round(tbuf, dict(
                cyc=cyc_round,
                cyc_total=cycles_acc,
                tile_busy=comp_all,
                crit_tile=jnp.argmax(comp_all).astype(jnp.int32),
                msgs=msgs_vec,
                spills=spills_vec,
                qdepth=glob(comm.psum(occ)),
                qdepth_max=glob(comm.pmax(occ)),
                chan_budget=glob(comm.psum(dyn_pops)),
                src_budget=glob(comm.psum(src_grant)),
                link_cls=(cls_onehot * link_g[None, :]).sum(axis=1),
                launches=jnp.int32(launch_tally.n),
                hbm_windows=hw_g,
                frontier=glob(comm.psum(comm.run(
                    lambda me, s: s.frontier.sum(dtype=jnp.int32), st))),
                pending=glob(pending),
            ), round_ix, cfg.trace_every)
        return st, stats, (c_cyc, c_en), tbuf, glob(pending)

    return rnd


def _bcast(comm, x):
    """Broadcast a global scalar back to per-device shape for comm.run."""
    if isinstance(comm, LocalComm):
        return jnp.broadcast_to(x, (comm.size,))
    return x


def init_state(comm, cfg: EngineConfig, v_chunk: int, value, frontier,
               alg=BFS, acc=None) -> EngineState:
    """value/frontier/acc: (T, v_chunk) under LocalComm, (v_chunk,) under
    Axis.  ``alg`` (AlgSpec or Program) fixes the channel queue shapes."""
    prog = as_program(alg)
    lead = (comm.size,) if isinstance(comm, LocalComm) else ()

    def mk_queue(ch):
        # allocated through the memory-space registry (repro.mem): the
        # channel's declared space is validated at config time.
        q = queue_make(ch.qcap(cfg), ch.width, space=ch.resolve_space(cfg),
                       label=f"queue[{ch.name}]")
        if lead:
            return Queue(jnp.broadcast_to(q.data, lead + q.data.shape),
                         jnp.broadcast_to(q.count, lead))
        return q

    if acc is None:
        acc = jnp.zeros(lead + (v_chunk,), jnp.float32)
    return EngineState(
        value=value,
        acc=acc,
        frontier=frontier,
        next_frontier=jnp.zeros(lead + (v_chunk,), bool),
        queues=tuple(mk_queue(ch) for ch in prog.channels),
        net_pressure=jnp.zeros(lead, jnp.int32),
    )


def run_engine(comm, cfg: EngineConfig, alg, shard: GraphShard,
               st: EngineState, e_chunk: int, v_chunk: int):
    """Run rounds until the global idle signal fires (or max_rounds).

    ``alg`` is an AlgSpec (compiled via ``classic_program``) or any
    :class:`repro.core.program.Program`.  Returns ``(state, stats,
    trace)`` — ``trace`` is the captured :class:`repro.trace.TraceBuf`
    ring when ``cfg.trace``, ``None`` otherwise (the trace-off carry is
    an empty pytree: byte-identical to a build without the recorder).
    """
    prog = as_program(alg)
    prog.validate(cfg, comm.size, e_chunk, v_chunk)
    net = make_network(cfg, comm.size)
    rnd = make_round(comm, net, cfg, prog, e_chunk, v_chunk, shard)
    tbuf0 = zero_trace(cfg, comm.size, prog) if cfg.trace else ()

    def cond(carry):
        _, _, _, _, pending, r = carry
        return (pending > 0) & (r < cfg.max_rounds)

    def body(carry):
        st, stats, kcomp, tbuf, _, r = carry
        st, stats, kcomp, tbuf, pending = rnd(st, stats, kcomp, tbuf)
        return st, stats, kcomp, tbuf, pending, r + 1

    pending0 = comm.to_global(comm.psum(comm.run(_pending, st)))
    zf = jnp.zeros((), jnp.float32)
    st, stats, _, tbuf, _, _ = jax.lax.while_loop(
        cond, body,
        (st, Stats.zero(net.num_links, net.max_hops, len(prog.channels),
                        net.max_die_crossings),
         (zf, zf), tbuf0, pending0, jnp.int32(0)))
    return st, stats, (tbuf if cfg.trace else None)
