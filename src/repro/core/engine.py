"""The Dalorex execution engine: data-local task-flow over a device grid.

One engine runs all five paper workloads (BFS, SSSP, PageRank, WCC, SpMV).
Per *round* (the vectorized analogue of a window of machine cycles), every
device executes the paper's task pipeline on its own shard:

  T4/T1  pop local frontier bits  -> edge-range tasks into the range queue
  T1b    pop range queue          -> bounded range *messages* (split at chunk
                                     borders and at MAX_T2, Listing 1)
         --- route by owner(edge_index) over the NoC backend ---
  T2     scan local edges         -> update messages (neighbor, value)
         --- route by owner(vertex_index) over the NoC backend ---
  T3     fold updates into local shard (scatter-min / scatter-add;
         atomic-free because this device is the only owner), set local
         frontier bits for improved vertices.

The fabric between stages is a pluggable :mod:`repro.noc` Network selected
by ``EngineConfig.noc``: the ideal crossbar (the original semantics), or a
physical mesh / torus / ruche grid with dimension-ordered routing, per-link
capacities, and per-link telemetry (``Stats.flits_per_link`` etc.).

Backpressure: routing capacity is finite (endpoint slots *and*, for the
physical NoCs, per-link flits); overflow *spills* back into the local queues
— of whichever tile the message is stranded at, since routes are re-derived
from the head flit — and is replayed next round, the software form of the
paper's "CQ full -> early exit, resume next invocation".  Nothing is ever
dropped; tests assert the ``drops == 0`` invariant.

Scheduling: per-round budgets are chosen per device from queue occupancies —
the Task Scheduling Unit's traffic-aware priorities (Section III-E), adapted
from per-cycle arbitration to per-round budget allocation:

  * drain the update queue first (its IQ filling up is the main source of
    end-point contention),
  * throttle range-message production while the update path is congested
    (keep consumer IQs from overflowing),
  * stop popping the frontier while the range queue is backed up (keep OQs
    non-empty but bounded).

``policy="static"`` reproduces the paper's round-robin/static arbitration
rung of the Fig. 5 ablation.

Synchronization: ``mode="async"`` is barrierless Dalorex — improved vertices
re-enter the *live* frontier immediately.  ``mode="bsp"`` defers them to a
next-epoch frontier that is swapped in only when the whole grid is quiescent
(the paper's per-epoch global barrier, driven by the same idle signal).

Termination is the paper's hierarchical idle wire: a psum of local pending
work (queue occupancy + frontier population); the loop exits when it hits
zero.  The whole traversal runs inside ONE ``lax.while_loop`` — on real
meshes there is no host round-trip per round.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import AxisComm, LocalComm
from repro.core.graph import PartitionedGraph
from repro.core.queues import (Queue, f2i, i2f, queue_make, queue_push,
                               queue_take_front)
from repro.noc import make_network


# --------------------------------------------------------------------------
# Algorithm specifications: the paper's T1/T2/T3 payload semantics.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlgSpec:
    """How values flow through the task pipeline.

    ``emit``   — T2's payload: f(parent_value, edge_value) for a neighbor.
    ``kind``   — T3's fold: "min" (relaxation; improvements re-enter the
                 frontier) or "add" (accumulation into ``acc``; single epoch).
    ``parent`` — what T1 loads from the local shard for a frontier vertex.
    """

    name: str
    kind: str  # "min" | "add"
    emit: str  # "plus1" | "plus_w" | "copy" | "times_w"
    parent: str = "value"  # "value" | "value_over_deg"


BFS = AlgSpec("bfs", "min", "plus1")
SSSP = AlgSpec("sssp", "min", "plus_w")
WCC = AlgSpec("wcc", "min", "copy")
PAGERANK = AlgSpec("pagerank", "add", "copy", parent="value_over_deg")
SPMV = AlgSpec("spmv", "add", "times_w")

INF = jnp.float32(np.finfo(np.float32).max)


def _emit(alg: AlgSpec, parent: jax.Array, w: jax.Array) -> jax.Array:
    if alg.emit == "plus1":
        return parent + 1.0
    if alg.emit == "plus_w":
        return parent + w
    if alg.emit == "copy":
        return parent
    if alg.emit == "times_w":
        return parent * w
    raise ValueError(alg.emit)


# --------------------------------------------------------------------------
# Engine configuration and state.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static knobs.  Sizes are per device; all shapes they imply are static.

    The queue/budget names mirror the paper:  ``cap_route_*`` are the channel
    queue (CQ) capacities *per destination*, ``max_t2`` is Listing 1's MAX_T2
    (edge-scan length bound per message), the ``*_pop`` budgets are the TSU's
    per-invocation drain amounts.
    """

    f_pop: int = 32          # frontier bits popped per round (T4 drain)
    r_pop: int = 32          # range-queue entries popped per round (T1 drain)
    u_pop: int = 64          # spilled updates replayed per round
    max_t2: int = 32         # edge-scan bound per range message (MAX_T2)
    cap_route_range: int = 16    # CQ1: range-message slots per destination
    cap_route_update: int = 64   # CQ2: update-message slots per destination
    cap_rangeq: int = 2048   # local range-queue capacity (IQ1)
    cap_updq: int = 16384    # local spilled-update queue capacity
    policy: str = "traffic"  # "traffic" | "static"
    mode: str = "async"      # "async" (barrierless) | "bsp"
    max_rounds: int = 100_000
    # --- NoC backend (repro.noc) ---
    noc: str = "ideal"       # "ideal" | "mesh" | "torus" | "ruche"
    noc_rows: int = 0        # grid rows; 0 = near-square factorization of T
    link_cap: int = 0        # flits per directed link per routing leg (a
                             # round has two legs: range + update); 0 = off
    ruche_factor: int = 2    # tiles skipped by a ruche channel (noc="ruche")

    def min_caps(self, T: int) -> tuple[int, int]:
        """Worst-case per-round queue inflow: (rangeq_need, updq_need).

        T2 output volume bounds the updq burst; physical NoCs additionally
        spill mid-route messages into the *waypoint* tile's queues, so a
        worst-case concentrated round (every inbound slot of both legs
        spilling here, plus this tile's own T1 remainder and source-spill
        re-pushes) must fit.  Sizing helpers and :meth:`validate` share
        these formulas — keep them in one place.
        """
        burst = T * self.cap_route_range * self.max_t2 + self.u_pop
        rangeq_need = 2 * self.f_pop
        if self.noc != "ideal":
            burst += T * self.cap_route_update
            rangeq_need += 2 * self.r_pop + T * self.cap_route_range
        return rangeq_need, burst

    def validate(self, T: int):
        # queues must absorb a full worst-case burst so the no-drop
        # invariant holds even under static scheduling.
        rangeq_need, burst = self.min_caps(T)
        assert self.cap_updq >= burst, (
            f"cap_updq={self.cap_updq} < worst-case T2 burst {burst}")
        assert self.cap_rangeq >= rangeq_need, (
            f"cap_rangeq={self.cap_rangeq} < worst-case inflow {rangeq_need}")


class EngineState(NamedTuple):
    value: jax.Array      # (v_chunk,) f32 — dist / label / rank / x
    acc: jax.Array        # (v_chunk,) f32 — "add" accumulator (y / rank acc)
    frontier: jax.Array   # (v_chunk,) bool — local bitmap frontier (live)
    next_frontier: jax.Array  # (v_chunk,) bool — BSP-deferred frontier
    rangeq: Queue         # pending edge-range tasks (start, end, parent_bits)
    updq: Queue           # spilled update messages (neighbor, value_bits)
    net_pressure: jax.Array  # () i32 — last round's occupancy on own links


class Stats(NamedTuple):
    rounds: jax.Array
    epochs: jax.Array           # BSP frontier swaps (1 in async mode)
    msgs_range: jax.Array       # range messages sent over the network
    msgs_update: jax.Array      # update messages sent over the network
    spills_range: jax.Array
    spills_update: jax.Array
    edges_scanned: jax.Array    # T2 work (== edges relaxed incl. replays)
    updates_applied: jax.Array  # valid T3 folds
    drops: jax.Array            # MUST be 0 — backpressure invariant
    work_max: jax.Array         # max per-device edges_scanned (balance)
    # --- NoC telemetry (shapes fixed by the Network backend) ---
    flits_per_link: jax.Array       # (num_links,) cumulative flit traversals
    max_link_occupancy: jax.Array   # () peak per-round per-link occupancy
    hop_histogram: jax.Array        # (max_hops+1,) injections by hop count

    @staticmethod
    def zero(num_links: int = 1, max_hops: int = 1):
        z = jnp.zeros((), jnp.int32)
        return Stats(z, z, z, z, z, z, z, z, z, z,
                     jnp.zeros((num_links,), jnp.int32), z,
                     jnp.zeros((max_hops + 1,), jnp.int32))


class GraphShard(NamedTuple):
    """One device's chunk of the four dataset arrays (placed space)."""
    ptr_start: jax.Array  # (v_chunk,) i32 global placed edge index
    deg: jax.Array        # (v_chunk,) i32
    edge_dst: jax.Array   # (e_chunk,) i32 placed dst (-1 pad)
    edge_val: jax.Array   # (e_chunk,) f32


# --------------------------------------------------------------------------
# Per-device pipeline stages (pure; run under comm.run -> vmap or shard_map).
# --------------------------------------------------------------------------

def _budgets(cfg: EngineConfig, st: EngineState, plimit: int):
    """The TSU: per-round budgets from queue occupancies AND link occupancy
    (Section III-E).  Queue counts expose endpoint congestion; the NoC's
    per-link occupancy from the previous round (``st.net_pressure``, fed
    back by the Network backend) exposes fabric congestion — a hot link on
    this tile's row/column throttles producers exactly like a filling IQ.
    ``plimit`` is the backend's own hot threshold (``net.pressure_limit``).
    """
    rq_free = jnp.int32(cfg.cap_rangeq) - st.rangeq.count
    if cfg.policy == "static":
        f_pop = jnp.minimum(jnp.int32(cfg.f_pop), jnp.maximum(rq_free, 0))
        r_pop = jnp.int32(cfg.r_pop)
        u_pop = jnp.int32(cfg.u_pop)
        return f_pop, r_pop, u_pop
    # traffic-aware: high priority = drain a nearly-full IQ; medium = feed a
    # nearly-empty OQ; throttle producers of congested consumers.
    net_hot = st.net_pressure > jnp.int32(max(plimit, 1))
    upd_congested = st.updq.count > (3 * cfg.cap_updq) // 4
    rng_congested = st.rangeq.count > cfg.cap_rangeq // 2
    u_pop = jnp.int32(cfg.u_pop)  # always drain updates first
    r_pop = jnp.where(upd_congested | net_hot, jnp.int32(cfg.r_pop // 4),
                      jnp.int32(cfg.r_pop))
    f_pop = jnp.where(rng_congested | upd_congested | net_hot, jnp.int32(0),
                      jnp.minimum(jnp.int32(cfg.f_pop),
                                  jnp.maximum(rq_free - 2 * cfg.f_pop, 0)))
    return f_pop, r_pop, u_pop


def _take_first_k(mask: jax.Array, k: jax.Array, k_max: int):
    """Indices of the first ``min(k, popcount)`` set bits, FIFO by position.

    Returns (idx (k_max,) i32, valid (k_max,) bool, cleared_mask)."""
    n = mask.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    take = mask & (rank < k)
    key = jnp.where(take, rank, jnp.int32(n) + ar)
    order = jnp.argsort(key)[:k_max]
    valid = take[order]
    return order.astype(jnp.int32), valid, mask & ~take


def _stage_a(me, cfg: EngineConfig, alg: AlgSpec, e_chunk: int,
             sh: GraphShard, st: EngineState, plimit: int):
    """T4 + T1: frontier -> range queue -> bounded range messages."""
    f_pop, r_pop, _ = _budgets(cfg, st, plimit)

    # T4: pop up to f_pop frontier vertices (paper: bitmap scan via IQ4).
    vidx, vvalid, frontier = _take_first_k(st.frontier, f_pop, cfg.f_pop)
    deg = sh.deg[vidx]
    start = sh.ptr_start[vidx]
    if alg.parent == "value_over_deg":
        parent = st.value[vidx] / jnp.maximum(deg, 1).astype(jnp.float32)
    else:
        parent = st.value[vidx]
    vvalid = vvalid & (deg > 0)
    rows = jnp.stack([start, start + deg, f2i(parent)], axis=1)
    rangeq, d0 = queue_push(st.rangeq, rows, vvalid)

    # T1: pop ranges; emit one bounded message each; push back the remainder.
    taken, tvalid, rangeq = queue_take_front(rangeq, r_pop, cfg.r_pop)
    t_start, t_end, t_pb = taken[:, 0], taken[:, 1], taken[:, 2]
    boundary = (t_start // e_chunk + 1) * e_chunk
    stop = jnp.minimum(jnp.minimum(t_end, boundary), t_start + cfg.max_t2)
    msgs = jnp.stack([t_start, stop, t_pb], axis=1)
    rem = jnp.stack([stop, t_end, t_pb], axis=1)
    rangeq, d1 = queue_push(rangeq, rem, tvalid & (stop < t_end))

    st = st._replace(frontier=frontier, rangeq=rangeq)
    return st, msgs, tvalid, d0 + d1


def _stage_b(me, cfg: EngineConfig, alg: AlgSpec, e_chunk: int, v_chunk: int,
             sh: GraphShard, st: EngineState, recv, recv_valid,
             spill, spill_valid, plimit: int):
    """T2: scan local edges for each received range message; emit updates.

    Also replays spilled range messages (back into the range queue) and pops
    previously spilled updates so they are retried ahead of fresh traffic.
    """
    rangeq, d0 = queue_push(st.rangeq, spill, spill_valid)

    r_start, r_stop, r_pb = recv[:, 0], recv[:, 1], recv[:, 2]
    length = jnp.where(recv_valid, r_stop - r_start, 0)
    local0 = jnp.where(recv_valid, r_start % e_chunk, 0)
    j = jnp.arange(cfg.max_t2, dtype=jnp.int32)[None, :]
    eidx = local0[:, None] + j                      # (R, MAX_T2)
    jvalid = recv_valid[:, None] & (j < length[:, None])
    eidx_c = jnp.minimum(eidx, e_chunk - 1)
    nb = sh.edge_dst[eidx_c]
    w = sh.edge_val[eidx_c]
    jvalid = jvalid & (nb >= 0)
    out = jnp.broadcast_to(_emit(alg, i2f(r_pb)[:, None], w), nb.shape)
    fresh = jnp.stack([nb.reshape(-1), f2i(out).reshape(-1)], axis=1)
    fresh_valid = jvalid.reshape(-1)
    edges = jvalid.sum(dtype=jnp.int32)

    _, _, u_pop = _budgets(cfg, st, plimit)
    replay, replay_valid, updq = queue_take_front(st.updq, u_pop, cfg.u_pop)
    upd = jnp.concatenate([replay, fresh], axis=0)
    uvalid = jnp.concatenate([replay_valid, fresh_valid], axis=0)

    st = st._replace(rangeq=rangeq, updq=updq)
    return st, upd, uvalid, edges, d0


def _stage_c(me, cfg: EngineConfig, alg: AlgSpec, v_chunk: int,
             st: EngineState, recv, recv_valid, spill, spill_valid):
    """T3: fold received updates into the local shard; grow the frontier."""
    updq, d0 = queue_push(st.updq, spill, spill_valid)

    nb, vb = recv[:, 0], recv[:, 1]
    lidx = jnp.where(recv_valid, nb % v_chunk, v_chunk)  # pad -> trash slot
    val = i2f(vb)
    applied = recv_valid.sum(dtype=jnp.int32)
    if alg.kind == "min":
        ext = jnp.concatenate([st.value, jnp.full((1,), INF, jnp.float32)])
        after = ext.at[lidx].min(jnp.where(recv_valid, val, INF))[:v_chunk]
        improved = after < st.value
        if cfg.mode == "async":
            st = st._replace(value=after, frontier=st.frontier | improved)
        else:
            st = st._replace(value=after,
                             next_frontier=st.next_frontier | improved)
    else:  # add
        ext = jnp.concatenate([st.acc, jnp.zeros((1,), jnp.float32)])
        acc = ext.at[lidx].add(jnp.where(recv_valid, val, 0.0))[:v_chunk]
        st = st._replace(acc=acc)
    return st._replace(updq=updq), applied, d0


def _pending(me, st: EngineState):
    return (st.rangeq.count + st.updq.count
            + st.frontier.sum(dtype=jnp.int32))


def _next_pending(me, st: EngineState):
    return st.next_frontier.sum(dtype=jnp.int32)


def _bsp_swap(me, st: EngineState, do_swap):
    frontier = jnp.where(do_swap, st.frontier | st.next_frontier, st.frontier)
    nxt = jnp.where(do_swap, jnp.zeros_like(st.next_frontier),
                    st.next_frontier)
    return st._replace(frontier=frontier, next_frontier=nxt)


# --------------------------------------------------------------------------
# The round + driver, parametric over the comm backend.
# --------------------------------------------------------------------------

def make_round(comm, net, cfg: EngineConfig, alg: AlgSpec, e_chunk: int,
               v_chunk: int, shard: GraphShard):
    """Build the per-round function (state, stats) -> (state, stats, pending).

    ``net`` is a :mod:`repro.noc` Network backend; both routing legs go
    through it, with the destination decoded from the head flit (the
    paper's headerless routing) — range messages are owned by the tile
    holding the edge chunk, updates by the tile owning the vertex.
    """

    plimit = net.pressure_limit(cfg)

    def stage_a(me, sh, st):
        return _stage_a(me, cfg, alg, e_chunk, sh, st, plimit)

    def stage_b(me, sh, st, recv, rv, sp, spv):
        return _stage_b(me, cfg, alg, e_chunk, v_chunk, sh, st, recv, rv,
                        sp, spv, plimit)

    def stage_c(me, st, recv, rv, sp, spv):
        return _stage_c(me, cfg, alg, v_chunk, st, recv, rv, sp, spv)

    def rnd(st: EngineState, stats: Stats):
        st, msgs, mvalid, drop_a = comm.run(stage_a, shard, st)
        routed = net.route(comm, msgs, mvalid, cfg.cap_route_range,
                           lambda m: m[..., 0] // e_chunk)
        st, upd, uvalid, edges, drop_b = comm.run(
            stage_b, shard, st, routed.recv, routed.recv_valid,
            routed.spill, routed.spill_valid)
        routed2 = net.route(comm, upd, uvalid, cfg.cap_route_update,
                            lambda m: m[..., 0] // v_chunk)
        st, applied, drop_c = comm.run(
            stage_c, st, routed2.recv, routed2.recv_valid,
            routed2.spill, routed2.spill_valid)

        # NoC telemetry: global per-link occupancy of this round, and the
        # per-tile pressure fed back into next round's TSU budgets.
        link_round = comm.psum(routed.link_flits + routed2.link_flits)
        hop_round = comm.psum(routed.hop_hist + routed2.hop_hist)
        st = st._replace(net_pressure=comm.run(
            lambda me, lf: net.pressure(me, lf), link_round))

        pending = comm.psum(comm.run(_pending, st))
        nxt = comm.psum(comm.run(_next_pending, st))
        if cfg.mode == "bsp":
            do_swap = (pending == 0) & (nxt > 0)
            st = comm.run(_bsp_swap, st, _bcast(comm, do_swap))
            epochs_inc = do_swap
            pending = pending + nxt
        else:
            epochs_inc = jnp.zeros_like(pending)

        spills_r = comm.psum(comm.run(
            lambda me, v: v.sum(dtype=jnp.int32), routed.spill_valid))
        spills_u = comm.psum(comm.run(
            lambda me, v: v.sum(dtype=jnp.int32), routed2.spill_valid))
        drops = comm.psum(drop_a + drop_b + drop_c)
        edges_t = comm.psum(edges)
        edges_m = comm.pmax(edges)
        glob = comm.to_global
        link_g = glob(link_round)
        stats = Stats(
            rounds=stats.rounds + 1,
            epochs=stats.epochs + glob(epochs_inc),
            msgs_range=stats.msgs_range + glob(comm.psum(routed.sent)),
            msgs_update=stats.msgs_update + glob(comm.psum(routed2.sent)),
            spills_range=stats.spills_range + glob(spills_r),
            spills_update=stats.spills_update + glob(spills_u),
            edges_scanned=stats.edges_scanned + glob(edges_t),
            updates_applied=stats.updates_applied
            + glob(comm.psum(applied)),
            drops=stats.drops + glob(drops),
            work_max=stats.work_max + glob(edges_m),
            flits_per_link=stats.flits_per_link + link_g,
            max_link_occupancy=jnp.maximum(stats.max_link_occupancy,
                                           link_g.max()),
            hop_histogram=stats.hop_histogram + glob(hop_round),
        )
        return st, stats, glob(pending)

    return rnd


def _bcast(comm, x):
    """Broadcast a global scalar back to per-device shape for comm.run."""
    if isinstance(comm, LocalComm):
        return jnp.broadcast_to(x, (comm.size,))
    return x


def init_state(comm, cfg: EngineConfig, v_chunk: int,
               value, frontier) -> EngineState:
    """value/frontier: (T, v_chunk) under LocalComm, (v_chunk,) under Axis."""
    lead = (comm.size,) if isinstance(comm, LocalComm) else ()

    def mk_queue(cap, w):
        q = queue_make(cap, w)
        if lead:
            return Queue(jnp.broadcast_to(q.data, lead + q.data.shape),
                         jnp.broadcast_to(q.count, lead))
        return q

    return EngineState(
        value=value,
        acc=jnp.zeros(lead + (v_chunk,), jnp.float32),
        frontier=frontier,
        next_frontier=jnp.zeros(lead + (v_chunk,), bool),
        rangeq=mk_queue(cfg.cap_rangeq, 3),
        updq=mk_queue(cfg.cap_updq, 2),
        net_pressure=jnp.zeros(lead, jnp.int32),
    )


def run_engine(comm, cfg: EngineConfig, alg: AlgSpec, shard: GraphShard,
               st: EngineState, e_chunk: int, v_chunk: int):
    """Run rounds until the global idle signal fires (or max_rounds)."""
    cfg.validate(comm.size)
    net = make_network(cfg, comm.size)
    rnd = make_round(comm, net, cfg, alg, e_chunk, v_chunk, shard)

    def cond(carry):
        _, _, pending, r = carry
        return (pending > 0) & (r < cfg.max_rounds)

    def body(carry):
        st, stats, _, r = carry
        st, stats, pending = rnd(st, stats)
        return st, stats, pending, r + 1

    pending0 = comm.to_global(comm.psum(comm.run(_pending, st)))
    st, stats, _, _ = jax.lax.while_loop(
        cond, body, (st, Stats.zero(net.num_links, net.max_hops), pending0,
                     jnp.int32(0)))
    return st, stats
