"""The Dalorex task-based programming model (paper contribution 2).

The paper's Listing 1 (BFS as T1/T2/T3 tasks) is *one program* in a general
model: arbitrary tasks execute at the tile that owns their target data, and
each task type has its own network channel with per-destination channel
queues (CQs).  This module is that model, lifted out of the engine:

* :class:`TaskSpec` — one task channel: message payload width, the owner
  function that decodes the destination tile from the head flit (headerless
  routing), the handler that runs at the owner (reads/writes the local shard
  slice and emits successor messages), CQ capacity, and local task-queue
  capacity/budget knobs.
* :class:`Program` — an ordered chain of task channels executed once per
  engine round (a DAG unrolled in channel order), plus the *source* that
  turns local frontier bits into the first channel's tasks (the paper's
  T4/T1 head).  ``engine.make_round`` iterates the channels generically:
  one ``queue -> budget -> route -> handler -> spill`` leg per channel.

Two queue disciplines exist, both from the paper:

* ``queued=True`` — a real task queue (the paper's IQ/OQ pair): fresh tasks
  are pushed in, the TSU budget pops them, and a ``transform`` turns each
  popped task into a bounded network message (the T1 range split of Listing
  1, ``MAX_T2``), re-pushing the remainder.  Spilled messages replay through
  the same queue; the split is idempotent on already-bounded messages.
* ``queued=False`` — a spill/replay queue only (the paper's "CQ full ->
  retry next invocation"): fresh messages go straight to the network behind
  the replayed backlog.

The five seed workloads compile to the classic 3-task program (T1 range
split -> T2 edge scan -> T3 fold) via :func:`classic_program`; k-core
peeling reuses the shape with a threshold fold; 2-hop triangle counting is
a 4-channel chain (range -> wedge -> second range at the neighbor's owner
-> intersection-count fold) that the old hard-wired pipeline could not
express.

Everything here is backend-agnostic: handlers are pure per-tile functions,
identical under ``LocalComm`` (vmap emulation) and ``AxisComm``
(shard_map SPMD).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queues import f2i, i2f
from repro.kernels.engine import (edge_scan_gather, edge_scan_stream,
                                  fold_scatter, frontier_pop, frontier_take,
                                  scatter_body, segment_gather,
                                  segment_stream)
from repro.mem import check_alloc, check_budgets, resolve_window

INF = jnp.float32(np.finfo(np.float32).max)


class Ctx(NamedTuple):
    """Static per-run context threaded to sources/transforms/handlers.

    ``backend`` is the execution backend of the *current* leg, resolved by
    ``engine.make_round`` from ``EngineConfig.backend`` and the channel's
    :attr:`TaskSpec.backend` hint — "xla" runs the building blocks inline,
    "pallas" dispatches them to the :mod:`repro.kernels.engine` tile-grid
    kernels (bit-identical by contract; see DESIGN.md "Pallas backend").

    ``fused`` means the *whole leg* is already executing inside one Pallas
    launch (``engine.make_round`` wrapped the stage in
    :func:`repro.kernels.engine.fused_leg_call`): the building blocks then
    call the pure kernel *bodies* inline — same ops, same bits — instead
    of nesting a ``pallas_call`` per block.

    ``edge_space`` is the resolved memory space of the tile's edge shard
    (``repro.mem``; "vmem" = word-random resident, "hbm" = consumed
    through double-buffered segment-DMA windows of ``hbm_window``
    elements) — resolved by ``engine.make_round`` from
    ``EngineConfig.edge_space`` and the Program's own pin via
    :func:`resolve_edge_space`; the :func:`edge_scan` building block
    dispatches on it.
    """

    cfg: object   # EngineConfig (static dataclass)
    T: int
    e_chunk: int
    v_chunk: int
    backend: str = "xla"
    fused: bool = False
    edge_space: str = "vmem"
    hbm_window: int = 0


def _interpret(ctx: Ctx) -> bool:
    return getattr(ctx.cfg, "pallas_interpret", True)


# --------------------------------------------------------------------------
# Legacy algorithm specifications (kept as the high-level front-end for the
# five paper workloads; they compile to Programs via classic_program).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlgSpec:
    """How values flow through the classic T1/T2/T3 pipeline.

    ``emit``   — T2's payload: f(parent_value, edge_value) for a neighbor.
    ``kind``   — T3's fold: "min" (relaxation; improvements re-enter the
                 frontier) or "add" (accumulation into ``acc``; single epoch).
    ``parent`` — what T1 loads from the local shard for a frontier vertex.
    """

    name: str
    kind: str  # "min" | "add"
    emit: str  # "plus1" | "plus_w" | "copy" | "times_w"
    parent: str = "value"  # "value" | "value_over_deg"


BFS = AlgSpec("bfs", "min", "plus1")
SSSP = AlgSpec("sssp", "min", "plus_w")
WCC = AlgSpec("wcc", "min", "copy")
PAGERANK = AlgSpec("pagerank", "add", "copy", parent="value_over_deg")
SPMV = AlgSpec("spmv", "add", "times_w")

# Name registry of the classic single-program workloads — the one place a
# front end (repro.serve, examples, benchmarks) resolves an app string to
# its spec.  The min-kind entries are the *point-query* apps: a single
# source vertex fully determines the run, which is what makes them
# servable as batched query lanes (repro.serve.lanes).
CLASSIC = {a.name: a for a in (BFS, SSSP, WCC, PAGERANK, SPMV)}
POINT_QUERY_APPS = tuple(sorted(n for n, a in CLASSIC.items()
                                if a.kind == "min" and n != "wcc"))


def _emit(alg: AlgSpec, parent: jax.Array, w: jax.Array) -> jax.Array:
    if alg.emit == "plus1":
        return parent + 1.0
    if alg.emit == "plus_w":
        return parent + w
    if alg.emit == "copy":
        return parent
    if alg.emit == "times_w":
        return parent * w
    raise ValueError(alg.emit)


# --------------------------------------------------------------------------
# TaskSpec / Program.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One task channel of a Program.

    ``owner`` decodes the destination tile from the head flit: the strings
    "edge" / "vertex" select the equal-chunk owner of a placed edge / vertex
    index (``idx // chunk``); a callable ``owner(ctx)`` may return any
    ``msgs -> dest`` function for custom routings.

    ``knobs`` selects which EngineConfig triple supplies the default CQ
    capacity, queue capacity and pop budget ("range" -> ``cap_route_range``
    / ``cap_rangeq`` / ``r_pop``; "update" -> ``cap_route_update`` /
    ``cap_updq`` / ``u_pop``); the explicit ``cap_route`` / ``queue_cap`` /
    ``pop`` fields override them per channel.

    ``handler(ctx, me, sh, st, recv, recv_valid) -> (st, rows, valid, work)``
    runs at the owner tile on this channel's delivered messages and emits
    rows for the *next* channel (the last channel's rows are ignored).
    ``work`` is a per-tile scalar attributed to Stats by the ``work`` tag
    ("edges" -> edges_scanned/work_max, "updates" -> updates_applied).

    ``emit_factor`` bounds handler fan-out per received message (the int, or
    "max_t2" for edge scans) — it feeds the worst-case inflow formula of
    ``Program.min_caps`` that sizes the successor channel's queue.

    ``backend`` is the per-channel execution-backend hint: ``None`` inherits
    ``EngineConfig.backend``; "xla" / "pallas" pin this channel's queue and
    handler legs regardless of the config (e.g. a channel whose handler has
    no kernel form can stay on "xla" while the rest of the program runs on
    the tile-grid kernels).  Handlers built from the dispatching building
    blocks below (``frontier_source`` / ``edge_scan`` / ``scatter_fold``)
    honor the resolved backend via ``Ctx.backend``.

    ``space`` declares the memory space of this channel's task/spill
    queue (``repro.mem``): ``None`` defaults to "vmem" — the queue is the
    tile's working set, the paper's scratchpad FIFO.  The registry
    validates the declaration at allocation time (HBM holds only bulk
    edge shards), and ``Program.validate`` charges it against the
    space's per-tile budget.
    """

    name: str
    width: int
    owner: Union[str, Callable] = "vertex"
    knobs: str = "update"
    handler: Optional[Callable] = None
    queued: bool = False
    transform: Optional[Callable] = None
    emit_factor: Union[int, str] = 1
    work: str = ""
    cap_route: Optional[int] = None
    queue_cap: Optional[int] = None
    pop: Optional[int] = None
    backend: Optional[str] = None
    space: Optional[str] = None

    def resolve_space(self, cfg) -> str:
        """The declared memory space of this channel's queue buffer."""
        s = self.space if self.space is not None else "vmem"
        check_alloc(s, "queue", f"queue[{self.name}]")
        return s

    def resolve_backend(self, cfg) -> str:
        """The execution backend of this channel's legs under ``cfg``."""
        b = self.backend if self.backend is not None else \
            getattr(cfg, "backend", "xla")
        assert b in ("xla", "pallas"), f"unknown backend {b!r}"
        return b

    def route_cap(self, cfg) -> int:
        if self.cap_route is not None:
            return self.cap_route
        return (cfg.cap_route_range if self.knobs == "range"
                else cfg.cap_route_update)

    def qcap(self, cfg) -> int:
        if self.queue_cap is not None:
            return self.queue_cap
        return cfg.cap_rangeq if self.knobs == "range" else cfg.cap_updq

    def pop_budget(self, cfg) -> int:
        if self.pop is not None:
            return self.pop
        return cfg.r_pop if self.knobs == "range" else cfg.u_pop

    def emit_bound(self, cfg) -> int:
        f = cfg.max_t2 if self.emit_factor == "max_t2" else self.emit_factor
        return int(f)

    def owner_fn(self, ctx: Ctx) -> Callable:
        if callable(self.owner):
            return self.owner(ctx)
        chunk = ctx.e_chunk if self.owner == "edge" else ctx.v_chunk
        return lambda m: m[..., 0] // chunk


@dataclasses.dataclass(frozen=True)
class Program:
    """An ordered chain of task channels plus the frontier source.

    Per round the engine runs ``source`` (T4: frontier bits -> channel-0
    tasks) and then each channel's generic leg in order; channel ``i``'s
    handler output feeds channel ``i+1``.  Feedback edges (a fold re-arming
    the frontier) close the DAG *across* rounds through the frontier bitmap,
    exactly like the paper's T3 -> T1 loop.

    Per-buffer memory-space declarations (``repro.mem``): ``edge_space``
    is the tile's edge shard — ``None`` leaves it configurable
    (``EngineConfig.edge_space`` picks "vmem" or "hbm" at run time); a
    program whose handlers need word-random access to the shard (e.g.
    triangles' closing binary search) *pins* it to "vmem", and asking the
    config for "hbm" anyway is a :func:`resolve_edge_space` error.
    ``state_space`` is the vertex state (value/acc/frontier bitmaps +
    ptr/deg) — always the tile's working set, so "vmem".  Channel queues
    declare their own space on each :class:`TaskSpec`.
    """

    name: str
    channels: tuple
    source: Optional[Callable] = None
    edge_space: Optional[str] = None
    state_space: str = "vmem"

    def min_caps(self, cfg, T: int) -> tuple:
        """Per-channel worst-case one-round queue inflow.

        Queued channels absorb fresh tasks plus their own re-pushed split
        remainders; spill-only channels absorb the predecessor handler's
        full burst behind the replay budget.  Physical NoCs additionally
        spill mid-route messages into *waypoint* queues, so every inbound
        CQ slot of the leg must fit too.  ``EngineConfig.validate`` keeps
        the closed-form twin of this for the classic program shape.
        """
        physical = cfg.noc != "ideal"
        deep = len(self.channels) > 2
        needs = []
        for i, ch in enumerate(self.channels):
            cap_i = ch.route_cap(cfg)
            pop_i = ch.pop_budget(cfg)
            if i == 0:
                feed = cfg.f_pop
            else:
                prev = self.channels[i - 1]
                feed = T * prev.route_cap(cfg) * prev.emit_bound(cfg)
            inflow = feed + pop_i
            if physical:
                inflow += pop_i + T * cap_i if ch.queued else T * cap_i
            if i == 0 and ch.queued:
                # the frontier source clamps itself to the queue's free
                # space, so the legacy 2x margin suffices.
                need = 2 * feed
                if physical:
                    need += 2 * pop_i + T * cap_i
            elif deep:
                # Mid-chain inflow is unclamped (routed messages must be
                # absorbed) and the TSU's congestion throttle only engages
                # the round *after* occupancy crosses the 3/4 threshold —
                # so the top quarter must hold a full one-round inflow:
                # cap >= 3/4*cap + inflow  <=>  cap >= 4*inflow.
                need = 4 * inflow
            else:
                # classic shape: the seed's empirically-validated burst
                # bound (EngineConfig.min_caps keeps the closed form).
                need = inflow
            needs.append(need)
        return tuple(needs)

    def validate(self, cfg, T: int, e_chunk: Optional[int] = None,
                 v_chunk: Optional[int] = None):
        """No-drop invariant (every task queue must absorb its worst-case
        one-round inflow, even under static scheduling) and — when the
        shard chunks are known — the per-tile memory budget: the total
        declared buffer footprint of each memory space must fit its
        capacity (:func:`repro.mem.check_budgets`), replacing what would
        otherwise surface as an opaque allocation failure mid-trace with
        a config-time error naming the offending buffer and space."""
        for ch, need in zip(self.channels, self.min_caps(cfg, T)):
            cap = ch.qcap(cfg)
            assert cap >= need, (
                f"program {self.name!r} channel {ch.name!r}: queue cap "
                f"{cap} < worst-case inflow {need}")
        if e_chunk is not None and v_chunk is not None:
            check_budgets(self.name, self.tile_decls(cfg, T, e_chunk,
                                                     v_chunk),
                          getattr(cfg, "vmem_limit_bytes", 0))

    def tile_decls(self, cfg, T: int, e_chunk: int, v_chunk: int) -> list:
        """Per-tile buffer declarations, one ``(label, space, bytes)``
        triple per engine buffer — the budget math of DESIGN.md "Memory
        spaces":

        * each channel's task/spill queue: ``qcap * width`` i32 words in
          the channel's declared space;
        * the vertex state: value/acc (f32), frontier/next_frontier
          (bool) and ptr_start/deg (i32) — 18 bytes per owned vertex in
          ``state_space``;
        * the edge shard: dst (i32) + val (f32) — 8 bytes per placed edge
          in the resolved edge space;
        * when the shard streams from HBM, the VMEM double-buffer the
          scan unit gathers through: 2 windows of 8-byte edge words per
          scan channel, charged against VMEM.  (A tile's scan unit
          drains one range message at a time, so the architectural
          staging is one double buffer per channel — the emulator's
          wider batch is a host-side artifact and is not charged.)
        """
        edge_space = resolve_edge_space(self, cfg)
        decls = [(f"queue[{ch.name}]", ch.resolve_space(cfg),
                  ch.qcap(cfg) * ch.width * 4) for ch in self.channels]
        decls.append(("vertex-state", self.state_space, 18 * v_chunk))
        decls.append((f"edge-shard[{self.name}]", edge_space, 8 * e_chunk))
        if edge_space == "hbm":
            window = resolve_window(getattr(cfg, "hbm_window", 0),
                                    cfg.max_t2)
            for ch in self.channels:
                if ch.work == "edges":
                    decls.append((f"dma-staging[{ch.name}]", "vmem",
                                  2 * window * 8))
        return decls


def resolve_edge_space(prog: Program, cfg) -> str:
    """The memory space of the tile's edge shard under ``cfg``.

    A program-level pin (``Program.edge_space``) wins: triangles pins
    "vmem" because its closing fold binary-searches the resident local
    adjacency word-random — asking the config for "hbm" anyway is a
    config error, not a silent de-optimization.  Unpinned programs take
    ``EngineConfig.edge_space``; the registry validates that the space
    can hold edge shards at all.
    """
    want = getattr(cfg, "edge_space", "vmem")
    if prog.edge_space is not None:
        if want not in ("vmem", prog.edge_space):
            raise ValueError(
                f"program {prog.name!r} pins its edge shard to "
                f"{prog.edge_space!r} (a handler needs word-random access "
                f"to the resident shard), but cfg.edge_space={want!r}")
        space = prog.edge_space
    else:
        space = want
    check_alloc(space, "edge", f"edge-shard[{prog.name}]")
    return space


def sized_cfg(cfg, program: Program, T: int):
    """Return ``cfg`` with ``cap_rangeq``/``cap_updq`` raised (next pow2)
    to satisfy ``program.validate`` — for programs whose channel inflow
    exceeds the classic defaults (e.g. triangles' second range channel).

    For deep chains (> 2 channels) ``min_caps`` already demands 4x the
    one-round inflow, so the TSU's stop-producers throttle has a full
    burst of headroom above its 3/4 congestion threshold.
    """
    rangeq, updq = cfg.cap_rangeq, cfg.cap_updq
    for ch, need in zip(program.channels, program.min_caps(cfg, T)):
        if ch.queue_cap is not None:
            continue
        need = 1 << (max(int(need), 1) - 1).bit_length()
        if ch.knobs == "range":
            rangeq = max(rangeq, need)
        else:
            updq = max(updq, need)
    return dataclasses.replace(cfg, cap_rangeq=rangeq, cap_updq=updq)


# --------------------------------------------------------------------------
# Reusable building blocks: frontier source, range split, edge scan, folds.
# --------------------------------------------------------------------------

def take_first_k(mask: jax.Array, k: jax.Array, k_max: int):
    """Indices of the first ``min(k, popcount)`` set bits, FIFO by position.

    Returns (idx (k_max,) i32, valid (k_max,) bool, cleared_mask)."""
    n = mask.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    take = mask & (rank < k)
    key = jnp.where(take, rank, jnp.int32(n) + ar)
    order = jnp.argsort(key)[:k_max]
    valid = take[order]
    return order.astype(jnp.int32), valid, mask & ~take


def frontier_source(payload: Callable) -> Callable:
    """T4: pop up to the TSU budget of frontier bits into channel-0 tasks
    ``(edge_start, edge_end, *payload)``.

    ``payload(ctx, me, sh, st, vidx, deg)`` returns the task's payload
    column(s) — (k,) or (k, P) int32 — e.g. the bitcast parent value for the
    classic workloads, or the placed vertex id for triangle counting.
    """

    def source(ctx: Ctx, me, sh, st, budget):
        if ctx.backend == "pallas":
            if ctx.fused:  # already inside the leg's single pallas_call
                vidx, vvalid, frontier = frontier_take(
                    st.frontier, budget, ctx.cfg.f_pop)
            else:
                vidx, vvalid, frontier = frontier_pop(
                    st.frontier, budget, ctx.cfg.f_pop,
                    interpret=_interpret(ctx))
        else:
            vidx, vvalid, frontier = take_first_k(st.frontier, budget,
                                                  ctx.cfg.f_pop)
        deg = sh.deg[vidx]
        start = sh.ptr_start[vidx]
        pay = payload(ctx, me, sh, st, vidx, deg)
        if pay.ndim == 1:
            pay = pay[:, None]
        vvalid = vvalid & (deg > 0)
        rows = jnp.concatenate([start[:, None], (start + deg)[:, None], pay],
                               axis=1)
        return st._replace(frontier=frontier), rows, vvalid

    return source


def range_split(ctx: Ctx, taken: jax.Array, tvalid: jax.Array):
    """Listing 1's T1: bound each popped range task at the chunk border and
    at MAX_T2; re-push the remainder.  Payload columns ride along, and the
    split is a no-op on already-bounded (spilled-and-replayed) messages."""
    t_start, t_end = taken[:, 0], taken[:, 1]
    boundary = (t_start // ctx.e_chunk + 1) * ctx.e_chunk
    stop = jnp.minimum(jnp.minimum(t_end, boundary),
                       t_start + ctx.cfg.max_t2)
    pay = taken[:, 2:]
    msgs = jnp.concatenate([t_start[:, None], stop[:, None], pay], axis=1)
    rem = jnp.concatenate([stop[:, None], t_end[:, None], pay], axis=1)
    return msgs, tvalid, rem, tvalid & (stop < t_end)


def edge_scan(emit_rows: Callable) -> Callable:
    """T2 skeleton: scan the local edge chunk for each received range
    message ``(start, stop, *payload)``.

    ``emit_rows(ctx, recv, nb, w, jvalid)`` maps the (R, MAX_T2) neighbor /
    weight grids to output rows (R, MAX_T2, W') and validity — the only
    part that differs between workloads.
    """

    def handler(ctx: Ctx, me, sh, st, recv, rv):
        r_start, r_stop = recv[:, 0], recv[:, 1]
        if ctx.edge_space == "hbm":
            # HBM-resident shard: both backends consume it through the
            # double-buffered segment-DMA stream (the xla path runs the
            # same pure body the fused kernel does — space equivalence
            # and backend equivalence hold by construction).
            if ctx.backend == "pallas" and not ctx.fused:
                nb, w, jvalid = edge_scan_stream(
                    sh.edge_dst, sh.edge_val, r_start, r_stop, rv,
                    ctx.cfg.max_t2, ctx.hbm_window,
                    interpret=_interpret(ctx))
            else:
                nb, w, jvalid = segment_stream(
                    sh.edge_dst, sh.edge_val, r_start, r_stop, rv,
                    ctx.cfg.max_t2, ctx.hbm_window)
        elif ctx.backend == "pallas":
            if ctx.fused:  # already inside the leg's single pallas_call
                nb, w, jvalid = segment_gather(
                    sh.edge_dst, sh.edge_val, r_start, r_stop, rv,
                    ctx.cfg.max_t2)
            else:
                nb, w, jvalid = edge_scan_gather(
                    sh.edge_dst, sh.edge_val, r_start, r_stop, rv,
                    ctx.cfg.max_t2, interpret=_interpret(ctx))
        else:
            length = jnp.where(rv, r_stop - r_start, 0)
            local0 = jnp.where(rv, r_start % ctx.e_chunk, 0)
            j = jnp.arange(ctx.cfg.max_t2, dtype=jnp.int32)[None, :]
            eidx = local0[:, None] + j                  # (R, MAX_T2)
            jvalid = rv[:, None] & (j < length[:, None])
            eidx_c = jnp.minimum(eidx, ctx.e_chunk - 1)
            nb = sh.edge_dst[eidx_c]
            w = sh.edge_val[eidx_c]
            jvalid = jvalid & (nb >= 0)
        rows, ov = emit_rows(ctx, recv, nb, w, jvalid)
        edges = jvalid.sum(dtype=jnp.int32)
        return st, rows.reshape(-1, rows.shape[-1]), ov.reshape(-1), edges

    return handler


def scatter_fold(ctx: Ctx, target: jax.Array, lidx: jax.Array,
                 vals: jax.Array, valid: jax.Array, op: str) -> jax.Array:
    """T3 scatter primitive shared by every fold: min/add ``vals[valid]``
    into ``target`` at local indices ``lidx`` (which must already map
    invalid rows to the trash slot ``target.shape[0]``).  Dispatches to the
    :func:`repro.kernels.engine.fold_scatter` kernel on the pallas backend;
    both paths are bit-identical (owner-local, atomic-free writes)."""
    if ctx.backend == "pallas":
        if ctx.fused:  # already inside the leg's single pallas_call
            return scatter_body(target, lidx, vals, valid, op)
        return fold_scatter(target, lidx, vals, valid, op=op,
                            interpret=_interpret(ctx))
    neutral = INF if op == "min" else jnp.float32(0.0)
    ext = jnp.concatenate([target, jnp.full((1,), neutral, jnp.float32)])
    masked = jnp.where(valid, vals, neutral)
    ext = ext.at[lidx].min(masked) if op == "min" else \
        ext.at[lidx].add(masked)
    return ext[:target.shape[0]]


def min_fold(ctx: Ctx, me, sh, st, recv, rv):
    """T3 for relaxations: scatter-min into ``value``; improved vertices
    re-enter the live (async) or next-epoch (BSP) frontier."""
    nb, vb = recv[:, 0], recv[:, 1]
    lidx = jnp.where(rv, nb % ctx.v_chunk, ctx.v_chunk)  # pad -> trash slot
    val = i2f(vb)
    applied = rv.sum(dtype=jnp.int32)
    after = scatter_fold(ctx, st.value, lidx, val, rv, "min")
    improved = after < st.value
    if ctx.cfg.mode == "async":
        st = st._replace(value=after, frontier=st.frontier | improved)
    else:
        st = st._replace(value=after,
                         next_frontier=st.next_frontier | improved)
    return st, None, None, applied


def add_fold(ctx: Ctx, me, sh, st, recv, rv):
    """T3 for accumulations: scatter-add into ``acc`` (atomic-free: this
    tile is the only owner)."""
    nb, vb = recv[:, 0], recv[:, 1]
    lidx = jnp.where(rv, nb % ctx.v_chunk, ctx.v_chunk)
    val = i2f(vb)
    applied = rv.sum(dtype=jnp.int32)
    acc = scatter_fold(ctx, st.acc, lidx, val, rv, "add")
    return st._replace(acc=acc), None, None, applied


# --------------------------------------------------------------------------
# The classic 3-task program (all five seed workloads).
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def classic_program(alg: AlgSpec) -> Program:
    """Compile an AlgSpec to the paper's Listing-1 program: T1 range split
    -> T2 edge scan (routed to the edge owner) -> T3 fold (routed to the
    neighbor's vertex owner).  Cached so jit sees one Program per AlgSpec."""

    if alg.parent == "value_over_deg":
        def payload(ctx, me, sh, st, vidx, deg):
            return f2i(st.value[vidx]
                       / jnp.maximum(deg, 1).astype(jnp.float32))
    else:
        def payload(ctx, me, sh, st, vidx, deg):
            return f2i(st.value[vidx])

    def emit_rows(ctx, recv, nb, w, jvalid):
        out = jnp.broadcast_to(_emit(alg, i2f(recv[:, 2])[:, None], w),
                               nb.shape)
        return jnp.stack([nb, f2i(out)], axis=-1), jvalid

    fold = min_fold if alg.kind == "min" else add_fold
    return Program(
        name=alg.name,
        source=frontier_source(payload),
        channels=(
            TaskSpec("range", width=3, owner="edge", knobs="range",
                     queued=True, transform=range_split,
                     handler=edge_scan(emit_rows), emit_factor="max_t2",
                     work="edges"),
            TaskSpec("update", width=2, owner="vertex", knobs="update",
                     handler=fold, work="updates"),
        ))


def as_program(alg) -> Program:
    """AlgSpec -> Program (cached); Programs pass through."""
    if isinstance(alg, Program):
        return alg
    return classic_program(alg)


# --------------------------------------------------------------------------
# k-core peeling: the classic shape with a threshold fold (different T3).
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def kcore_program(k: int) -> Program:
    """Peel the k-core: removed vertices emit one decrement per out-edge;
    the fold subtracts and re-arms the frontier when a still-alive vertex
    drops below k (``acc`` is the removed flag).  Requires a symmetrized,
    deduplicated graph; converges to the same fixed point in async and BSP
    modes (peeling is order-independent)."""
    kf = float(k)
    one = np.int32(np.float32(1.0).view(np.int32))

    def payload(ctx, me, sh, st, vidx, deg):
        return jnp.full(vidx.shape, one, jnp.int32)

    def emit_rows(ctx, recv, nb, w, jvalid):
        dec = jnp.full(nb.shape, one, jnp.int32)
        return jnp.stack([nb, dec], axis=-1), jvalid

    def fold(ctx, me, sh, st, recv, rv):
        nb, vb = recv[:, 0], recv[:, 1]
        lidx = jnp.where(rv, nb % ctx.v_chunk, ctx.v_chunk)
        dec = i2f(vb)
        applied = rv.sum(dtype=jnp.int32)
        after = scatter_fold(ctx, st.value, lidx, -dec, rv, "add")
        newly = (st.acc == 0.0) & (after < jnp.float32(kf))
        acc = jnp.where(newly, jnp.float32(1.0), st.acc)
        if ctx.cfg.mode == "async":
            st = st._replace(value=after, acc=acc,
                             frontier=st.frontier | newly)
        else:
            st = st._replace(value=after, acc=acc,
                             next_frontier=st.next_frontier | newly)
        return st, None, None, applied

    return Program(
        name=f"kcore{k}",
        source=frontier_source(payload),
        channels=(
            TaskSpec("range", width=3, owner="edge", knobs="range",
                     queued=True, transform=range_split,
                     handler=edge_scan(emit_rows), emit_factor="max_t2",
                     work="edges"),
            TaskSpec("decrement", width=2, owner="vertex", knobs="update",
                     handler=fold, work="updates"),
        ))


# --------------------------------------------------------------------------
# 2-hop triangle counting: a 4-channel chain the fixed pipeline could not
# express (range -> wedge -> second range at the neighbor's owner ->
# intersection-count fold).
# --------------------------------------------------------------------------

def _segment_contains(edge_dst: jax.Array, lo, deg, target):
    """Vectorized bounded binary search: is ``target`` in the sorted local
    segment ``edge_dst[lo : lo+deg]``?  Static log2(e_chunk)+1 steps."""
    e_chunk = edge_dst.shape[0]
    left, right = lo, lo + deg
    for _ in range(max(1, int(e_chunk).bit_length())):
        has = left < right
        mid = (left + right) // 2
        val = edge_dst[jnp.clip(mid, 0, e_chunk - 1)]
        go = has & (val < target)
        left = jnp.where(go, mid + 1, left)
        right = jnp.where(has & ~go, mid, right)
    at = edge_dst[jnp.clip(left, 0, e_chunk - 1)]
    return (left < lo + deg) & (at == target)


def _make_triangles_program() -> Program:
    """Count each triangle once at its placed-minimum vertex: wedges
    v -> u -> w with v < u < w (placed order) close iff w is in adj(v).

    Requires a ``prepare_triangles`` partition: vertex-aligned edges (each
    tile owns its vertices' full adjacency) with per-vertex segments sorted
    by placed destination, so the closing check is a local binary search.
    """

    def payload(ctx, me, sh, st, vidx, deg):
        return me * ctx.v_chunk + vidx  # placed vertex id

    def scan1_rows(ctx, recv, nb, w, jvalid):
        v = recv[:, 2][:, None]
        rows = jnp.stack([nb, jnp.broadcast_to(v, nb.shape)], axis=-1)
        return rows, jvalid & (nb > v)

    def wedge_to_range(ctx, me, sh, st, recv, rv):
        # At u's owner: look up u's adjacency range, emit the second-hop
        # range task (start, end, v, u).
        u, v = recv[:, 0], recv[:, 1]
        lidx = jnp.where(rv, u % ctx.v_chunk, 0)
        start = sh.ptr_start[lidx]
        deg = sh.deg[lidx]
        rows = jnp.stack([start, start + deg, v, u], axis=1)
        return st, rows, rv & (deg > 0), jnp.zeros((), jnp.int32)

    def scan2_rows(ctx, recv, nb, w, jvalid):
        v = recv[:, 2][:, None]
        u = recv[:, 3][:, None]
        rows = jnp.stack([jnp.broadcast_to(v, nb.shape), nb], axis=-1)
        return rows, jvalid & (nb > u)

    def close_fold(ctx, me, sh, st, recv, rv):
        # At v's owner: does the closing edge (v, w) exist?  v's full
        # adjacency is local (vertex-aligned) and sorted (prepare).
        v, w = recv[:, 0], recv[:, 1]
        lidx = jnp.where(rv, v % ctx.v_chunk, 0)
        lo = sh.ptr_start[lidx] % ctx.e_chunk
        deg = sh.deg[lidx]
        found = _segment_contains(sh.edge_dst, lo, deg, w) & rv
        slot = jnp.where(rv, lidx, ctx.v_chunk)
        acc = scatter_fold(ctx, st.acc, slot, found.astype(jnp.float32),
                           rv, "add")
        return (st._replace(acc=acc), None, None,
                found.sum(dtype=jnp.int32))

    return Program(
        name="triangles",
        source=frontier_source(payload),
        # close_fold binary-searches the resident local adjacency
        # word-random — the shard must stay VMEM-resident (pinned; a
        # cfg.edge_space="hbm" request is a resolve_edge_space error).
        edge_space="vmem",
        channels=(
            TaskSpec("range", width=3, owner="edge", knobs="range",
                     queued=True, transform=range_split,
                     handler=edge_scan(scan1_rows), emit_factor="max_t2",
                     work="edges"),
            TaskSpec("wedge", width=2, owner="vertex", knobs="update",
                     handler=wedge_to_range, emit_factor=1),
            TaskSpec("range2", width=4, owner="edge", knobs="range",
                     queued=True, transform=range_split,
                     handler=edge_scan(scan2_rows), emit_factor="max_t2",
                     work="edges"),
            TaskSpec("close", width=2, owner="vertex", knobs="update",
                     handler=close_fold, work="updates"),
        ))


TRIANGLES = _make_triangles_program()
