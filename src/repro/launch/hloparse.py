"""HLO-text parsing helpers for the dry-run (importable WITHOUT
touching jax device state — dryrun.py sets XLA_FLAGS at import)."""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of one HLO shape literal like 'bf16[2,4096,8192]{2,1,0}'."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, per kind.

    Matches lines like:
      %ag = bf16[2,512]{1,0} all-gather(...), replica_groups=...
      %ar = (f32[8]{0}, f32[4]{0}) all-reduce(...)
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    pat = re.compile(
        r"=\s*(\(?[a-z0-9]+\[[0-9,]*\][^)\s]*\)?[^=]*?)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(")
    shape_pat = re.compile(r"[a-z0-9]+\[[0-9,]*\]")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        kinds = m.group(2)
        shapes = shape_pat.findall(m.group(1))
        nbytes = sum(_shape_bytes(s) for s in shapes)
        out[kinds] += nbytes
        counts[kinds] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


