"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Prefill + batched greedy decode with the ring-buffer KV cache.  On a real
pod this runs with the weights-stationary DECODE_RULES layout (see
launch/mesh.rules_for(kind="decode")); in this container it serves the
reduced configs on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import transformer as tfm

    cfg = get_config(args.arch).reduced()
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (B, P), 0, cfg.vocab_size, jnp.int32)
    cache = tfm.init_cache(cfg, B, tfm.cache_slots(cfg, P + G))
    t0 = time.perf_counter()
    _, cache = tfm.prefill(params, cfg, cache, {"tokens": prompts})
    print(f"prefill {B}x{P}: {(time.perf_counter()-t0)*1e3:.0f} ms")
    step = jax.jit(lambda p, c, t: tfm.serve_step(p, cfg, c, t))
    tok = prompts[:, -1:]
    t0 = time.perf_counter()
    for _ in range(G):
        nxt, cache = step(params, cache, tok)
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decode {B}x{G}: {dt*1e3:.0f} ms ({B*G/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
