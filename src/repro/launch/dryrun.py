import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
the production mesh (16x16 single-pod, 2x16x16 multi-pod) and extract the
roofline terms from the compiled artifact.

The two lines above MUST run before any other import — jax locks the device
count at first initialization.

Per cell we record:
  * memory_analysis()      — proves the cell fits per-device HBM,
  * cost_analysis()        — HLO FLOPs / bytes for the roofline,
  * collective bytes       — parsed from the partitioned HLO text, summed
                             per collective kind (all-gather, all-reduce,
                             reduce-scatter, all-to-all, collective-permute),
  * analytic MODEL_FLOPS   — 6·N·D (dense) / 6·N_active·D (MoE),
  * the three roofline terms in seconds (v5e: 197 TF/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) and the dominant bottleneck.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod/--single-pod/--both]
Artifacts: one JSON per cell under --out (default benchmarks/artifacts/).
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALL_SHAPES, SHAPES, get_config, list_archs, \
    shape_applicable  # noqa: E402
from repro.launch import specs as sp  # noqa: E402
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: E402
from repro.models import transformer as tfm  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel.sharding import mesh_context  # noqa: E402
from repro.runtime.trainer import TrainConfig, make_train_step  # noqa: E402

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link

from repro.launch.hloparse import (_COLLECTIVES, _DTYPE_BYTES,  # noqa
                                  _shape_bytes, collective_bytes)


def roofline(cell: dict) -> dict:
    """The three roofline terms (seconds) + dominant bottleneck.

    compute term uses analytic MODEL_FLOPS (the MFU convention — the HLO
    flop counter sees a scan body once); memory/collective terms prefer the
    probe-extrapolated totals (exact per-layer counts from the unrolled
    two-point probe) and fall back to the raw full-compile counts.
    """
    flops_meas = cell["cost_analysis"].get("flops", 0.0) or 0.0
    probe = cell.get("probe", {})
    flops_hlo = probe.get("flops_est", flops_meas)
    bytes_acc = probe.get("bytes_est",
                          cell["cost_analysis"].get("bytes accessed", 0.0))
    coll = probe.get("collective_bytes_est",
                     cell["collectives"]["total_bytes"])
    model_fl = cell.get("model_flops_per_device", 0.0)
    t_compute = model_fl / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    frac = model_fl / flops_hlo if flops_hlo else 0.0
    # ideal step time: compute at peak OR the unavoidable per-step streaming
    # (weights once; + the KV/state cache once for decode), whichever binds.
    ideal = max(t_compute, cell.get("min_bytes_per_device", 0.0) / HBM_BW)
    return {**terms, "dominant": dom,
            "hlo_flops_est": flops_hlo,
            "useful_flop_fraction": frac,
            "ideal_s": ideal,
            "roofline_fraction": ideal / max(max(terms.values()), 1e-30)}


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the whole step (all devices)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def _lower_cell(cfg, shape, mesh, rules):
    """Build + lower + compile one cell; returns (compiled, t_lower, t_comp)."""
    t0 = time.time()
    with mesh_context(mesh, rules):
        pstructs, pspecs = sp.param_structs(cfg, mesh, rules)
        batch = sp.input_specs(cfg, shape, mesh, rules)
        if shape.kind == "train":
            tc = TrainConfig(remat=True)
            step = make_train_step(cfg, tc)
            ostructs = sp.opt_structs(pspecs, mesh, rules, tc.opt)
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(pstructs, ostructs, batch)
        elif shape.kind == "prefill":
            cache = sp.cache_structs(cfg, shape, mesh, rules)
            fn = jax.jit(
                lambda p, c, b: tfm.prefill(p, cfg, c, b),
                donate_argnums=(1,))
            lowered = fn.lower(pstructs, cache, batch)
        else:  # decode
            cache = sp.cache_structs(cfg, shape, mesh, rules)
            fn = jax.jit(
                lambda p, c, t: tfm.serve_step(p, cfg, c, t),
                donate_argnums=(1,))
            lowered = fn.lower(pstructs, cache, batch["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, lowered, t_lower, t_compile


def _measure(compiled, lowered) -> dict:
    ca = compiled.cost_analysis() or {}
    if not ca.get("flops"):
        ca = dict(ca, **(lowered.cost_analysis() or {}))
    coll = collective_bytes(compiled.as_text())
    return {"flops": ca.get("flops", 0.0) or 0.0,
            "bytes": ca.get("bytes accessed", 0.0) or 0.0,
            "collectives": coll}


def _probe_layers(cfg) -> tuple[int, int, int, int]:
    """(L1, L2, unit, n_units) for the two-point extrapolation."""
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    base = cfg.first_dense_layers
    L1, L2 = base + unit, base + 2 * unit
    n_units = (cfg.num_layers - base) // unit
    return L1, L2, unit, n_units


def probe_roofline(cfg, shape, mesh, rules) -> dict:
    """Two-point unrolled probe: per-layer-unit exact HLO counts, scaled to
    the full depth.  Collective counts are exact (all collectives sit at
    layer granularity); compute-only inner scans (attention tiles, wkv/ssd
    chunks) stay rolled and are noted as a flop-counter diagnostic."""
    import dataclasses
    L1, L2, unit, n_units = _probe_layers(cfg)
    out = {"L1": L1, "L2": L2, "n_units": n_units}
    metrics = []
    for L in (L1, L2):
        c = dataclasses.replace(cfg, num_layers=L, scan_unroll=True)
        compiled, lowered, _, t = _lower_cell(c, shape, mesh, rules)
        metrics.append(_measure(compiled, lowered))
        out[f"probe_compile_s_L{L}"] = round(t, 2)
    m1, m2 = metrics
    for key in ("flops", "bytes"):
        per = m2[key] - m1[key]
        fixed = m1[key] - per
        out[f"{key}_per_unit"] = per
        out[f"{key}_fixed"] = fixed
        out[f"{key}_est"] = fixed + per * n_units
    per_c = m2["collectives"]["total_bytes"] - m1["collectives"]["total_bytes"]
    fixed_c = m1["collectives"]["total_bytes"] - per_c
    out["collective_bytes_per_unit"] = per_c
    out["collective_bytes_fixed"] = fixed_c
    out["collective_bytes_est"] = fixed_c + per_c * n_units
    out["collective_kind_bytes_est"] = {
        k: (m1["collectives"]["bytes"][k]
            + (m2["collectives"]["bytes"][k]
               - m1["collectives"]["bytes"][k]) * (n_units - 1))
        for k in m1["collectives"]["bytes"]}
    return out


def use_serving_layout(cfg, shape) -> bool:
    """Weights-stationary serving layout pays when the per-token weight
    gather would dominate: batched decode, or models whose weights cannot
    replicate across the data axis anyway (experts > ~8 GB/model-shard).
    For single-stream decode of small models, the trainer layout's
    2D-sharded weights + partial-psum contractions already win (measured:
    rwkv6/zamba2 long_500k) — real serving stacks make exactly this
    layout choice per deployment."""
    if shape.kind != "decode":
        return False
    weight_gb_per_shard = cfg.param_count() * 2 / 16 / 2**30
    return shape.global_batch >= 16 or weight_gb_per_shard > 8


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = True, probe: bool = None,
             tag: str = "", serving_rules: bool = False) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    name = f"{arch}__{shape_name}__{mesh_tag}"
    if tag:
        name += f"__{tag}"
    path = os.path.join(out_dir, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "tag": tag, "status": "skipped", "reason": why}
    if not ok:
        _write(path, cell)
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh, kind=shape.kind
                      if serving_rules and use_serving_layout(cfg, shape)
                      else "train")
    cell["num_devices"] = mesh.devices.size
    if probe is None:
        probe = not multi_pod  # roofline table is single-pod per the spec
    try:
        compiled, lowered, t_lower, t_compile = _lower_cell(
            cfg, shape, mesh, rules)
        ca = compiled.cost_analysis() or {}
        if not ca.get("flops"):
            ca = dict(ca, **(lowered.cost_analysis() or {}))
        mem = compiled.memory_analysis()
        mem_d = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            mem_d[field] = getattr(mem, field, None)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        mf = model_flops(cfg, shape)
        # unavoidable per-device streaming: weights once per step (active
        # experts only for MoE decode; all experts train fwd+bwd), plus the
        # cache for decode steps
        dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
        if shape.kind == "decode":
            wb = cfg.active_param_count() * dtype_bytes
        else:
            wb = cfg.param_count() * dtype_bytes
        min_bytes = wb / mesh.devices.size
        if shape.kind == "decode":
            cache_spec = tfm.abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len)
            import numpy as _np
            from repro.parallel.sharding import ParamSpec as _PS
            cache_bytes = sum(
                _np.prod(s.shape) * (2 if s.dtype == "bfloat16" else 4)
                for s in jax.tree.leaves(
                    cache_spec, is_leaf=lambda x: isinstance(x, _PS))
                if isinstance(s, _PS))
            min_bytes += cache_bytes / mesh.devices.size
        cell.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_analysis": {k: ca.get(k) for k in
                              ("flops", "bytes accessed",
                               "transcendentals") if k in ca},
            "memory_analysis": mem_d,
            "collectives": coll,
            "params_total": cfg.param_count(),
            "params_active": cfg.active_param_count(),
            "model_flops_global": mf,
            "model_flops_per_device": mf / mesh.devices.size,
            "min_bytes_per_device": min_bytes,
            "hlo_instruction_count": hlo.count("\n"),
        })
        del compiled, lowered, hlo
        if probe:
            cell["probe"] = probe_roofline(cfg, shape, mesh, rules)
        cell["roofline"] = roofline(cell)
    except Exception as e:  # record the failure, keep sweeping
        cell.update({"status": "error", "error": repr(e),
                     "traceback": traceback.format_exc()[-3000:]})
    _write(path, cell)
    return cell


def _write(path, cell):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(cell, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument("--serving-rules", action="store_true",
                    help="weights-stationary layout for decode cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                t0 = time.time()
                cell = run_cell(arch, shape, mp, args.out,
                                skip_existing=not args.force,
                                tag=args.tag,
                                serving_rules=args.serving_rules)
                dt = time.time() - t0
                status = cell["status"]
                extra = ""
                if status == "ok":
                    r = cell["roofline"]
                    extra = (f" dom={r['dominant']} "
                             f"rf={r['roofline_fraction']:.3f}")
                elif status == "error":
                    extra = " " + cell["error"][:120]
                print(f"[{status:7s}] {arch:22s} {shape:12s} "
                      f"{'pod2' if mp else 'pod1'} ({dt:5.1f}s){extra}",
                      flush=True)
                results.append(cell)
    n_ok = sum(c["status"] == "ok" for c in results)
    n_err = sum(c["status"] == "error" for c in results)
    n_skip = sum(c["status"] == "skipped" for c in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
