"""ShapeDtypeStruct stand-ins for every model input — the dry-run feeds
these to .lower(); nothing is allocated.  Sharded per the active rules with
the same divisibility-drop logic the runtime constraints use."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel.sharding import (AxisRules, ParamSpec, clean_spec,
                                     tree_structs)


def struct(shape, dtype, logical_axes, mesh, rules):
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=NamedSharding(mesh, clean_spec(shape, logical_axes, mesh,
                                                rules)))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    """Batch stand-ins for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": struct((B, 1), jnp.int32, ("batch", None),
                                  mesh, rules)}
        return batch
    if cfg.frontend == "vision":
        s_text = S - cfg.num_patches
        return {
            "tokens": struct((B, s_text), jnp.int32, ("batch", "seq"),
                             mesh, rules),
            "patches": struct((B, cfg.num_patches,
                               tfm.FRONTEND_DIM["vision"]), jnp.float32,
                              ("batch", None, None), mesh, rules),
        }
    if cfg.frontend == "audio":
        return {
            "tokens": struct((B, S), jnp.int32, ("batch", "seq"),
                             mesh, rules),
            "frames": struct((B, S, tfm.FRONTEND_DIM["audio"]), jnp.float32,
                             ("batch", "seq", None), mesh, rules),
        }
    return {"tokens": struct((B, S), jnp.int32, ("batch", "seq"),
                             mesh, rules)}


def param_structs(cfg: ModelConfig, mesh, rules):
    specs = tfm.abstract_params(cfg, moe_shards=mesh.shape["model"])
    return tree_structs(specs, mesh, rules), specs


def opt_structs(param_specs, mesh, rules, oc: adamw.OptConfig):
    """OptState stand-ins: master/mu/nu share the parameter shardings."""
    def f32(s: ParamSpec):
        return ParamSpec(s.shape, s.axes, "float32", s.init, s.scale)
    is_ps = lambda x: isinstance(x, ParamSpec)
    master = tree_structs(jax.tree.map(f32, param_specs, is_leaf=is_ps),
                          mesh, rules)
    mu = tree_structs(jax.tree.map(f32, param_specs, is_leaf=is_ps),
                      mesh, rules)
    nu = tree_structs(jax.tree.map(f32, param_specs, is_leaf=is_ps),
                      mesh, rules)
    ef = master if oc.compress_grads else None
    return adamw.OptState(
        jax.ShapeDtypeStruct((), jnp.int32,
                             sharding=NamedSharding(mesh, P())),
        master, mu, nu, ef)


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    spec = tfm.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    return tree_structs(spec, mesh, rules)
