"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the production training loop on the available devices.  On a real pod
this binary runs per host under the cluster scheduler (auto-resume makes
restarts free); in this container it runs the reduced/100M variants on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--size", choices=["reduced", "100m", "full"],
                    default="reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.optim.adamw import OptConfig
    from repro.runtime.trainer import TrainConfig, train

    cfg = get_config(args.arch)
    if args.size == "reduced":
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab_size=max(cfg.vocab_size, 512))
    elif args.size == "100m":
        from examples.train_lm import scale_to_100m
        cfg = scale_to_100m(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M")

    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches, log_every=10,
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps,
                      compress_grads=args.compress_grads))
    _, _, hist = train(cfg, tc)
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
