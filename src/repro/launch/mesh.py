"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def auto_mesh(shape, axes):
    """make_mesh across jax versions: AxisType.Auto where it exists (>=0.5),
    plain mesh otherwise (older jax is Auto-only anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return auto_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small CPU mesh for tests/examples (requires forced host devices)."""
    return auto_mesh((data, model), ("data", "model"))


def rules_for(mesh, kind: str = "train"):
    from repro.parallel.sharding import (DECODE_RULES, DECODE_RULES_MULTI,
                                         MULTI_POD_RULES, SINGLE_POD_RULES)
    multi = "pod" in mesh.shape
    if kind == "decode":  # weights-stationary serving layout (§Perf iter 1)
        return DECODE_RULES_MULTI if multi else DECODE_RULES
    return MULTI_POD_RULES if multi else SINGLE_POD_RULES
