"""Logical-axis sharding: one rule table maps model-space axis names to mesh
axes (MaxText-style), so every architecture shares a single parallelism
vocabulary:

  batch   -> data (+ pod)     pure data parallelism
  fsdp    -> data             ZeRO-3 parameter/optimizer sharding
  seq     -> model            sequence parallelism between blocks
  heads   -> model            tensor parallelism (attention heads)
  mlp     -> model            tensor parallelism (hidden dim)
  expert  -> model            expert parallelism (Dalorex-routed dispatch)
  vocab   -> model            vocab-sharded embedding / LM head
  kv_seq  -> model            decode: sequence-sharded KV cache
                              (flash-decode; the Dalorex move — cache stays,
                              query visits)
  stage   -> pod              pipeline stages (optional)

Rules are plain data; the dry-run and tests swap them per mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axis name (or tuple of names, or None)."""

    table: tuple[tuple[str, object], ...]

    def get(self, name: str | None):
        if name is None:
            return None
        for k, v in self.table:
            if k == name:
                return v
        return None

    def spec(self, logical_axes: tuple[str | None, ...]) -> P:
        mesh_axes = [self.get(a) for a in logical_axes]
        # A mesh axis may appear at most once in a PartitionSpec.
        seen = set()
        out = []
        for m in mesh_axes:
            ms = m if isinstance(m, tuple) else (m,) if m else ()
            keep = tuple(x for x in ms if x not in seen)
            seen.update(keep)
            out.append(keep if len(keep) != 1 else keep[0])
        out = [o if o != () else None for o in out]
        return P(*out)


SINGLE_POD_RULES = AxisRules((
    ("batch", ("data",)),
    ("fsdp", "data"),
    ("seq", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("vocab", "model"),
    ("kv_seq", "model"),
    ("stage", None),
))

MULTI_POD_RULES = AxisRules((
    ("batch", ("pod", "data")),
    ("fsdp", "data"),
    ("seq", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("vocab", "model"),
    # decode caches shard their slot axis across pods too (32-way for the
    # 500k single-stream cell, where batch cannot shard)
    ("kv_seq", ("pod", "model")),
    ("stage", None),
))

PIPELINE_RULES = MULTI_POD_RULES  # with ("stage", "pod") override via replace

# Decode (serving) rules — §Perf iteration 1.  Training FSDP re-gathers
# weights every step; amortized over 1M train tokens that is cheap, but a
# decode step touches every weight for ONE token per sequence, so the
# gather dominates (mixtral decode_32k baseline: 703 ms collective vs
# 0.2 ms compute).  Serving keeps weights STATIONARY:
#   * fsdp -> None: dense/attention weights replicated over `data`
#     (resident; the model axis still shards them 16-way);
#   * expert_ff -> data: the big MoE expert weights get their ff dimension
#     sharded over `data` (2D: slots over model x ff over data), so
#     mixtral's 277 GB of experts still fits and is NEVER moved — every
#     data-row computes its ff-slice of every dispatched token and the
#     slice partials psum (Dalorex: the weight is the immovable data).
DECODE_RULES = AxisRules((
    ("batch", ("data",)),
    ("fsdp", None),
    ("expert_ff", "data"),
    ("seq", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("vocab", "model"),
    ("kv_seq", "model"),
    ("stage", None),
))

DECODE_RULES_MULTI = AxisRules((
    ("batch", ("pod", "data")),
    ("fsdp", None),
    ("expert_ff", "data"),
    ("seq", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),
    ("vocab", "model"),
    ("kv_seq", ("pod", "model")),
    ("stage", None),
))


def with_rule(rules: AxisRules, name: str, value) -> AxisRules:
    return AxisRules(tuple((k, value if k == name else v)
                           for k, v in rules.table))


# --------------------------------------------------------------------------
# Thread-local context: active (mesh, rules) used by logical constraints.
# --------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: AxisRules | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: AxisRules | None):
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def current_rules() -> AxisRules | None:
    return _CTX.rules


def clean_spec(shape, logical_axes, mesh, rules: "AxisRules") -> P:
    """PartitionSpec for ``shape``, dropping axes whose dimension is not
    divisible by the assigned mesh axes (e.g. kv_heads=8 over model=16, or a
    "seq" constraint on a decode step's length-1 axis)."""
    spec = rules.spec(logical_axes)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    cleaned = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            cleaned.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        cleaned.append(entry if dim % size == 0 and dim >= size else None)
    return P(*cleaned)


def lsc(x, *logical_axes):
    """Logical sharding constraint: no-op outside a mesh context, so the same
    model code runs single-device (tests) and fully sharded (dry-run/train).
    """
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = clean_spec(x.shape, logical_axes, _CTX.mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def gathered(w, *logical_axes):
    """Pre-gather a tensor in ITS OWN dtype and pin it with an optimization
    barrier, so the SPMD partitioner cannot hoist the fp32 compute-precision
    convert above the collective (halves weight all-gather bytes — §Perf
    train iteration A3).  No-op outside a mesh context."""
    if _CTX.mesh is None or _CTX.rules is None:
        return w
    w = lsc(w, *logical_axes)
    return jax.lax.optimization_barrier(w)


def sharding_for(logical_axes: tuple[str | None, ...]):
    """NamedSharding for the active mesh (None outside a context)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return None
    return NamedSharding(_CTX.mesh, _CTX.rules.spec(logical_axes))


# --------------------------------------------------------------------------
# Parameter specs: shape + dtype + logical axes, materialized lazily.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: str = "float32"
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def sharded_struct(self, mesh, rules) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(
            self.shape, self.dtype,
            sharding=NamedSharding(
                mesh, clean_spec(self.shape, self.axes, mesh, rules)))


def materialize(key, spec: ParamSpec):
    import jax.numpy as jnp
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
    if spec.init == "embed":
        std = spec.scale
    else:
        std = spec.scale / (fan_in ** 0.5)
    return (jax.random.normal(key, spec.shape, "float32") * std
            ).astype(spec.dtype)


def init_tree(key, specs):
    """Materialize a pytree of ParamSpec with per-leaf folded keys."""
    import jax.numpy as jnp  # noqa: F401
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [materialize(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def tree_structs(specs, mesh=None, rules=None):
    """ShapeDtypeStructs (optionally sharded) for a ParamSpec tree — this is
    what the dry-run feeds to .lower(); no memory is allocated."""
    def one(s: ParamSpec):
        if mesh is not None and rules is not None:
            return s.sharded_struct(mesh, rules)
        return s.struct()
    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shardings(specs, mesh, rules):
    def one(s: ParamSpec):
        return NamedSharding(mesh, clean_spec(s.shape, s.axes, mesh, rules))
    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
