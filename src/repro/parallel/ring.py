"""Ring attention (context parallelism) — §Perf train iteration B.

Sequence stays sharded; the (small, GQA-compact) kv blocks rotate around the
model axis with ``ppermute`` while every device accumulates online-softmax
partials for its own q block.  Per layer this moves M-1 kv blocks
(~kv_bytes), replacing the (B,S,d)-sized activation gathers of the
gather-style attention — for internlm2 train_4k: 252 MB vs ~4.5 GB.

Differentiable end to end (ppermute transposes to the reverse ring); remat
recomputes the ring in the backward pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import shard_map_compat

NEG_INF = -1e30


def _block_scores(q, k, qpos, kpos, window):
    """q: (B, Sq, Hkv, G, hd); k: (B, Sk, Hkv, hd) -> (B, Hkv, G, Sq, Sk)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    mask = kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(mask[None, None, None], s, NEG_INF)


def ring_attention(q, k, v, *, mesh, model_axis: str = "model",
                   batch_axes=("data",), window: int = 0):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd), S sharded over model_axis.

    Returns (B, S, H, hd), same sharding as q.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    M = mesh.shape[model_axis]
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def body(qb, kb, vb):
        # qb: (B_l, S_loc, H, hd); kb/vb: (B_l, S_loc, Hkv, hd)
        idx = jax.lax.axis_index(model_axis)
        S_loc = qb.shape[1]
        qg = qb.reshape(qb.shape[0], S_loc, Hkv, G, hd)
        qpos = idx * S_loc + jnp.arange(S_loc, dtype=jnp.int32)
        perm = [(i, (i + 1) % M) for i in range(M)]

        def step(carry, t):
            m, l, acc, kc, vc = carry
            src = (idx - t) % M  # original owner of the block in hand
            kpos = src * S_loc + jnp.arange(S_loc, dtype=jnp.int32)
            # skip fully-masked blocks: future blocks (causal) and blocks
            # beyond the sliding window never touch the accumulators —
            # ~2x compute saved causal, ~M/(window/S_loc) for SWA
            kmin = src * S_loc
            kmax = kmin + S_loc - 1
            qmin, qmax = idx * S_loc, (idx + 1) * S_loc - 1
            relevant = kmin <= qmax  # some kv position is <= some q
            if window:
                relevant &= kmax > qmin - window

            def attend(args):
                m, l, acc = args
                s = _block_scores(qg, kc, qpos, kpos, window)
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l2 = l * corr + p.sum(-1)
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                vc.astype(jnp.float32),
                                preferred_element_type=jnp.float32)
                return m_new, l2, acc * corr[..., None] + pv

            m, l, acc = jax.lax.cond(relevant, attend,
                                     lambda args: args, (m, l, acc))
            kc = jax.lax.ppermute(kc, model_axis, perm)
            vc = jax.lax.ppermute(vc, model_axis, perm)
            return (m, l, acc, kc, vc), None

        Bl = qb.shape[0]
        # sliding window: only ceil(window/S_loc)+1 source blocks can ever
        # be visible — the ring stops early (STATIC; device-independent).
        # causal-only skips stay dynamic (lax.cond) inside the step.
        n_steps = M
        if window:
            n_steps = min(M, -(-window // S_loc) + 1)
        m0 = jnp.full((Bl, Hkv, G, S_loc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((Bl, Hkv, G, S_loc), jnp.float32)
        a0 = jnp.zeros((Bl, Hkv, G, S_loc, hd), jnp.float32)
        (m, l, acc, _, _), _ = jax.lax.scan(
            step, (m0, l0, a0, kb, vb),
            jnp.arange(n_steps, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, G, S_loc, hd) -> (B, S_loc, H, hd)
        return out.transpose(0, 3, 1, 2, 4).reshape(
            Bl, S_loc, H, hd).astype(qb.dtype)

    spec_q = P(bspec, model_axis, None, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec_q, spec_q, spec_q),
        out_specs=spec_q)
    return fn(q, k, v)
