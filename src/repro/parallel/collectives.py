"""Distributed-optimization collectives.

* int8 error-feedback gradient compression (compressed DP all-reduce):
  quantize(g + residual) -> int8 psum -> dequantize; the quantization error
  is carried to the next step, so the compressed optimizer converges to the
  same fixed point (convergence-parity test in tests/test_optim.py).
* flash-decode softmax merge (used implicitly by GSPMD in decode attention;
  the explicit helper is exposed for shard_map users and tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g):
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, residual, axis: str | None):
    """One error-feedback compressed all-reduce (SUM) for a single tensor.

    g: local fp32 gradient; residual: carried quantization error.
    All shards agree on a shared scale (one scalar pmax — negligible bytes),
    quantize to int8, psum in int32, dequantize: the result is the *exact*
    sum of the quantized values, and each shard's quantization error rides
    the residual into the next step.  With axis=None (single device) the
    collective degenerates but the quantization numerics stay identical, so
    tests exercise the exact production path.
    """
    x = g + residual
    amax = jnp.max(jnp.abs(x))
    if axis is not None:
        amax = jax.lax.pmax(amax, axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_residual = x - deq_local
    if axis is None:
        return deq_local, new_residual
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale, new_residual


def compress_tree(grads, residuals, axis: str | None):
    """Apply compressed_psum leaf-wise.  Returns (grads, residuals)."""
    pairs = jax.tree.map(lambda g, r: compressed_psum(g, r, axis),
                         grads, residuals)
    g = jax.tree.map(lambda t: t[0], pairs,
                     is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda t: t[1], pairs,
                     is_leaf=lambda x: isinstance(x, tuple))
    return g, r


def flash_decode_merge(m, l, o, axis: str):
    """Merge per-shard partial-softmax triples across a sharded KV axis.

    m: (...,) running max; l: (...,) exp-sum; o: (..., d) weighted values.
    """
    m_all = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_all)
    l_all = jax.lax.psum(l * corr, axis)
    o_all = jax.lax.psum(o * corr[..., None], axis)
    return o_all / jnp.maximum(l_all, 1e-30)[..., None]
