"""GPipe-style pipeline parallelism over a mesh axis (shard_map+ppermute).

Each device on the ``stage`` axis owns one stage's parameters (never moved —
the Dalorex discipline again: weights are the immovable data, activations
are the routed messages).  Microbatches flow through a static schedule of
n_micro + n_stages - 1 ticks; stage outputs hop one link per tick via
``ppermute``; the last stage accumulates results which are psum-broadcast at
the end.  Differentiable end to end (ppermute transposes to the reverse
permutation), so the same function trains.

Bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1) — callers pick
n_micro >> n_stages; the roofline harness reports it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import shard_map_compat


def pipeline_apply(stage_fn, params, x, *, mesh, axis: str, n_micro: int):
    """params: pytree with leading (n_stages,) axis on every leaf.
    x: (n_micro, mb, ...) microbatched input.  Returns (n_micro, mb, ...).

    ``stage_fn(stage_params, x_mb) -> y_mb`` must be shape-preserving
    (classic homogeneous-stage pipelining; heterogeneous stages wrap their
    own padding).
    """
    n_stages = mesh.shape[axis]

    def body(prm, xs):
        prm = jax.tree.map(lambda a: a[0], prm)  # this device's stage
        idx = jax.lax.axis_index(axis)
        xs = xs  # (n_micro, mb, ...) replicated input
        mb_shape = xs.shape[1:]
        carry = jnp.zeros(mb_shape, xs.dtype)
        outs = jnp.zeros_like(xs)
        fwd = [(i, i + 1) for i in range(n_stages - 1)]
        for t in range(n_micro + n_stages - 1):
            inject = xs[jnp.minimum(t, n_micro - 1)]
            x_in = jnp.where(idx == 0, inject, carry)
            y = stage_fn(prm, x_in)
            active = (t >= idx) & (t - idx < n_micro)
            y = jnp.where(active, y, 0)
            # emit from the last stage
            out_slot = t - (n_stages - 1)
            is_out = (idx == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.cond(
                is_out,
                lambda o: o.at[jnp.maximum(out_slot, 0)].set(y),
                lambda o: o, outs)
            carry = jax.lax.ppermute(y, axis, fwd)
        # broadcast the last stage's outputs to every device
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, 0), axis)
        return outs

    pspecs = jax.tree.map(lambda _: P(axis), params)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P())
    return fn(params, x)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
