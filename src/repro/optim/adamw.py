"""AdamW with fp32 master weights, global-norm clipping, LR schedules, and
an optional int8 error-feedback gradient-compression hook.

Optimizer state is sharded exactly like the parameters (ZeRO-3 falls out of
the fsdp axis rules), so memory per device is params/N * (2 bytes bf16 +
12 bytes fp32 master+m+v).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_ratio: float = 0.1
    compress_grads: bool = False  # int8 error-feedback DP all-reduce


class OptState(NamedTuple):
    step: jax.Array
    master: dict      # fp32 master weights
    mu: dict
    nu: dict
    ef: dict | None   # error-feedback residuals (compression only)


def schedule_lr(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(oc.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    if oc.schedule == "cosine":
        decay = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif oc.schedule == "linear":
        decay = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * (1 - frac)
    else:
        decay = 1.0
    return oc.lr * warm * decay


def init(params, oc: OptConfig) -> OptState:
    # force a copy: fp32 params must NOT alias the master buffer (donation)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    ef = jax.tree.map(zeros, params) if oc.compress_grads else None
    return OptState(jnp.zeros((), jnp.int32), jax.tree.map(f32, params),
                    jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                    ef)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, state: OptState, grads, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gn, 1e-9)) \
        if oc.clip_norm else 1.0
    lr = schedule_lr(oc, step)
    b1, b2 = oc.beta1, oc.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def one(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        m = m - lr * (mhat / (jnp.sqrt(nhat) + oc.eps)
                      + oc.weight_decay * m)
        return m, mu, nu

    out = jax.tree.map(one, grads, state.master, state.mu, state.nu)
    master = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, OptState(step, master, mu, nu, state.ef), {
        "grad_norm": gn, "lr": lr}
