"""Memory spaces for tile buffers: a declared, validated VMEM/HBM split.

Dalorex's core claim is that every memory operation is tile-local — but
"local" does not have to mean "in the tile's SRAM".  This module makes the
memory space of each task-channel buffer (queue, edge shard, vertex state)
a *declared attribute* with per-space capacity, window-granularity and
allocation rules — the Exo/SYS_ATL custom-``Memory`` idiom — instead of an
implicit "everything fits in VMEM" assumption baked into the kernels:

* :class:`MemSpace` — one addressable space: per-tile capacity in bytes,
  the DMA window granularity (elements) for streamed spaces, and the
  buffer *kinds* it may hold (``"queue"`` / ``"edge"`` / ``"state"``).
* The registry — ``VMEM`` (the tile's fast scratchpad: every kind, no
  streaming), ``HBM`` (large, ``streamed=True``: holds edge shards that
  the engine consumes through double-buffered segment DMA windows —
  see ``kernels/engine/kernel.py::segment_stream``), and ``HOST`` (a
  registered placeholder for a future host-memory spill tier: declared
  now so configs can name it, allocatable later — ``kinds=()`` makes any
  allocation a clear config-time error instead of a silent fiction).
* :func:`alloc` / :func:`check_alloc` — every engine buffer allocation
  goes through here, so placing a buffer in a space that cannot hold its
  kind fails at config time with the buffer's *label* in the message,
  not as an opaque Pallas allocation failure mid-trace.
* :func:`footprint_bytes` / :func:`space_budget` — the budget math
  ``Program.validate`` uses to check each tile's total declared footprint
  against the per-space capacity (DESIGN.md "Memory spaces").
* :func:`resolve_window` — the DMA window sizing rule: a window must
  cover MAX_T2 (one bounded range message), because the double-buffer
  correctness argument is "any MAX_T2-bounded segment fits in two
  consecutive windows".

Spaces are *priced* separately by the perf model (``t_hbm`` / ``e_hbm``
vs ``t_sram`` / ``e_sram`` in :mod:`repro.perf`), and per-space traffic
surfaces as ``Stats.hbm_windows`` / ``Stats.hbm_edges``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: Buffer kinds a space may be asked to hold.
KINDS = ("queue", "edge", "state")


@dataclasses.dataclass(frozen=True)
class MemSpace:
    """One addressable memory space of a tile.

    ``capacity_bytes`` is the per-tile budget ``Program.validate`` checks
    declared footprints against.  ``window`` is the minimum DMA transfer
    granularity in *elements* for streamed spaces (VMEM is word-random:
    window 1).  ``kinds`` lists the buffer kinds allocatable here — HBM
    holds only bulk ``"edge"`` shards (queues and vertex state are the
    tile's working set and stay SRAM-resident, like the paper's
    scratchpad FIFOs).  ``streamed`` marks spaces the engine may only
    touch through windowed DMA, never word-at-a-time.
    """

    name: str
    capacity_bytes: int
    window: int = 1
    kinds: tuple = KINDS
    streamed: bool = False


#: The registry.  VMEM capacity defaults to the TPU-core-like 16 MiB the
#: tile-grid kernels actually get; override per run with
#: ``EngineConfig.vmem_limit_bytes`` to model smaller paper-era tiles.
VMEM = MemSpace("vmem", capacity_bytes=16 * 1024 * 1024)
HBM = MemSpace("hbm", capacity_bytes=8 * 1024 * 1024 * 1024, window=128,
               kinds=("edge",), streamed=True)
HOST = MemSpace("host", capacity_bytes=64 * 1024 * 1024 * 1024, window=4096,
                kinds=(), streamed=True)  # future spill tier: not yet
                                          # allocatable (kinds=())

_REGISTRY: dict = {}


def register(space: MemSpace) -> MemSpace:
    """Add (or replace) a space in the registry; returns it."""
    _REGISTRY[space.name] = space
    return space


for _s in (VMEM, HBM, HOST):
    register(_s)


def get_space(name: str) -> MemSpace:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown memory space {name!r}; registered: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def check_alloc(space: str, kind: str, label: str) -> MemSpace:
    """Validate that buffer ``label`` of ``kind`` may live in ``space``.

    Raises ``ValueError`` naming the buffer and the space — the
    config-time twin of what would otherwise surface as an opaque
    allocation failure inside a kernel trace.
    """
    sp = get_space(space)
    assert kind in KINDS, f"unknown buffer kind {kind!r}"
    if kind not in sp.kinds:
        holds = f"holds only {sp.kinds}" if sp.kinds else \
            "is not yet allocatable (a declared future tier)"
        raise ValueError(
            f"buffer {label!r} (kind {kind!r}) cannot live in memory "
            f"space {sp.name!r}: {sp.name!r} {holds}")
    return sp


def alloc(space: str, kind: str, shape: tuple, dtype, label: str):
    """Allocate a zeroed buffer in ``space`` after :func:`check_alloc`.

    This is the single chokepoint engine buffers are created through
    (``core/queues.queue_make``), so a bad declaration fails here with
    the buffer's label, before any kernel traces.
    """
    check_alloc(space, kind, label)
    return jnp.zeros(shape, dtype)


def footprint_bytes(shape: tuple, dtype) -> int:
    """Declared size of one buffer, in bytes."""
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def space_budget(space: str, override_bytes: int = 0) -> int:
    """The per-tile capacity to validate against: the registry's, unless
    the run overrides it (``EngineConfig.vmem_limit_bytes`` models a
    smaller tile without re-registering the space)."""
    return int(override_bytes) if override_bytes else \
        get_space(space).capacity_bytes


def resolve_window(cfg_window: int, max_t2: int) -> int:
    """The DMA window (elements) for an HBM-resident edge shard.

    ``cfg_window == 0`` auto-sizes to the next power of two >= MAX_T2 and
    >= the space's transfer granularity.  An explicit window smaller than
    MAX_T2 is a config error: the double-buffer correctness argument
    (DESIGN.md "Memory spaces") requires any MAX_T2-bounded segment to
    fit in two consecutive windows, i.e. ``window >= max_t2``.
    """
    gran = get_space("hbm").window
    if cfg_window == 0:
        w = 1 << (max(int(max_t2), 1) - 1).bit_length()
        return max(w, gran)
    if cfg_window < max_t2:
        raise ValueError(
            f"hbm_window={cfg_window} < max_t2={max_t2}: a DMA window "
            f"must cover one bounded range message (the double-buffer "
            f"invariant); use hbm_window=0 to auto-size")
    return int(cfg_window)


def check_budgets(program_name: str, decls: list, vmem_limit_bytes: int = 0):
    """Validate per-tile declared footprints against each space's budget.

    ``decls`` is a list of ``(label, space, nbytes)`` declarations — one
    per tile buffer.  Sums per space and raises ``ValueError`` naming the
    program, the over-budget space, the totals, and the single largest
    offending buffer (the one to move or shrink).  Called by
    ``Program.validate``; unit-tested in ``tests/test_memspace.py``.
    """
    by_space: dict = {}
    for label, space, nbytes in decls:
        by_space.setdefault(space, []).append((label, int(nbytes)))
    for space, bufs in sorted(by_space.items()):
        budget = space_budget(
            space, vmem_limit_bytes if space == "vmem" else 0)
        total = sum(b for _, b in bufs)
        if total > budget:
            big_label, big_bytes = max(bufs, key=lambda lb: lb[1])
            raise ValueError(
                f"program {program_name!r}: memory space {space!r} over "
                f"budget on a tile: declared buffers total {total} B > "
                f"{budget} B capacity; largest buffer is {big_label!r} "
                f"({big_bytes} B in {space!r}) — move it to another "
                f"space (e.g. EngineConfig.edge_space='hbm' for the edge "
                f"shard) or raise the budget")
