"""First-order cycle and energy cost model for the Dalorex engine.

The engine counts *rounds* — vectorized windows of machine cycles — and
per-round telemetry (per-tile work, per-link flits).  This module prices
that telemetry into cycles and picojoules so the benchmarks can report
time, GTEPS and energy like the paper's Fig. 6/7/10, instead of raw round
counts.

Model (accumulated once per engine round, see ``engine.make_round``):

  cycles_round = t_round
               + max over tiles of (pops * t_pop + pushes * t_push
                                    + spill_replays * t_spill
                                    + edges * t_scan + updates * t_fold)
               + max over links of (flits * t_hop(link_class))

The first max is the compute critical path — the slowest tile gates the
round, exactly like ``Stats.work_max`` gates work balance.  The second is
the NoC serialization term: a link that carried F flits this round needed
at least ``F * t_hop`` cycles of wire time, and links of different classes
are priced differently (``noc.topology`` attributes every directed link to
a class: LOCAL neighbor hop, RUCHE express channel, torus WRAP-around,
hier die-to-die DIE link).

  energy_round = edges * e_scan + updates * e_fold
               + msgs * (e_push + e_pop) + spills * e_spill
               + sum over links of (flits * e_hop(link_class))
               + T * cycles_round * e_leak_tile_cycle

Energy is linear in the global Stats counters, so the accumulated total
reconciles exactly (up to f32 rounding) with :func:`energy_from_totals`
applied to the final Stats — the property the tests pin down.

Caveats vs RTL: this is a first-order model — no pipelining overlap
between compute and NoC inside a round, conservative per-round critical
path (max-of-sums, not a scheduled pipeline), and constants are 22nm-era
estimates, not the paper's RTL synthesis numbers.  Trends (scaling knees,
topology/placement/policy ladders) are meaningful; absolute numbers carry
the usual factor-of-a-few analytical-model error.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# Link classes are attributed by the NoC layer (Network.link_classes /
# noc.topology) and priced here.  PORT is the ideal crossbar's ingress
# ports: endpoint serialization is already the per-tile compute term
# (handlers process one message per event), so a perfect fabric adds no
# wire latency — but each crossbar traversal still costs switch energy.
from repro.noc.topology import (CLASS_DIE, CLASS_LOCAL,  # noqa: F401
                                CLASS_PORT, CLASS_RUCHE, CLASS_WRAP,
                                N_LINK_CLASSES)


@dataclasses.dataclass(frozen=True)
class PerfParams:
    """Per-op cycle/energy constants (22nm-era, ~1 GHz tile defaults).

    Cycle costs are in tile cycles; energies in pJ.  The defaults are
    first-order estimates in the spirit of the paper's 22nm evaluation
    (small in-order core + SRAM tile + one-way NoC): a 64-bit local SRAM
    access costs a couple of cycles and ~5 pJ; a router hop moves one flit
    per cycle at ~2 pJ; express (ruche) and torus wraparound links drive
    physically longer wires, so they pay more energy per flit (and the
    wrap a latency penalty).  Every field is overridable — the model is
    parameterized, not baked in.
    """

    f_ghz: float = 1.0        # tile clock, GHz (time_s = cycles / f_ghz e9)
    # --- cycle costs ---
    t_alu: int = 1            # one core ALU op
    t_sram: int = 2           # one local SRAM access (64-bit word)
    t_pop: int = 1            # queue pop (TSU dequeue + head-flit decode)
    t_push: int = 1           # queue push
    t_spill: int = 2          # spill replay re-enqueue
    t_hop_local: int = 1      # router traversal, neighbor link
    t_hop_ruche: int = 1      # express channel hop (router bypass)
    t_hop_wrap: int = 2       # torus wraparound (longest wire on the line)
    t_hop_port: int = 0       # ideal-crossbar port: no wire serialization
    t_hop_die: int = 4        # die-to-die express link (serdes + off-die
                              # wire: slowest hop class, fewest links)
    t_hbm: int = 4            # per 64-bit edge word streamed from an
                              # HBM-resident shard (amortized over the
                              # double-buffered DMA window; conservative
                              # no-overlap, like the rest of the model)
    t_round: int = 1          # fixed per-round pipeline overhead
    t_migrate: int = 2        # per 64-bit word of migrated vertex state /
                              # edge segment (SRAM read + write at the new
                              # owner; the NoC hop cost is priced on top
                              # via the hop tables)
    # --- energy costs (pJ) ---
    e_alu: float = 0.5
    e_sram: float = 5.0
    e_pop: float = 1.0
    e_push: float = 1.0
    e_spill: float = 2.0
    e_hop_local: float = 2.0
    e_hop_ruche: float = 4.0  # ruche_factor-long wire per hop
    e_hop_wrap: float = 5.0   # ring-closing return wire
    e_hop_port: float = 2.0   # ideal-crossbar switch traversal
    e_hop_die: float = 12.0   # off-die serdes crossing (hier backend)
    e_hbm: float = 250.0      # per 64-bit edge word streamed from HBM
                              # (~3.9 pJ/bit, HBM2-era — the ~50x-vs-SRAM
                              # gap the UPMEM/PIM literature prices; the
                              # reason "move compute to the data" wins)
    e_migrate: float = 10.0   # per migrated 64-bit word (paired SRAM
                              # read + write; hop energy priced on top)
    e_leak_tile_cycle: float = 0.05  # static leakage, per tile per cycle

    # Derived per-event costs of the two handler kinds ("edges"-tagged
    # scans read one (dst, val) word and emit; "updates"-tagged folds do a
    # read-modify-write plus the fold ALU op).
    @property
    def t_scan(self) -> int:
        return self.t_sram + self.t_alu

    @property
    def t_fold(self) -> int:
        return 2 * self.t_sram + self.t_alu

    @property
    def e_scan(self) -> float:
        return self.e_sram + self.e_alu

    @property
    def e_fold(self) -> float:
        return 2 * self.e_sram + self.e_alu

    def hop_cycle_table(self) -> np.ndarray:
        t = np.zeros(N_LINK_CLASSES, np.float32)
        t[CLASS_LOCAL] = self.t_hop_local
        t[CLASS_RUCHE] = self.t_hop_ruche
        t[CLASS_WRAP] = self.t_hop_wrap
        t[CLASS_PORT] = self.t_hop_port
        t[CLASS_DIE] = self.t_hop_die
        return t

    def hop_energy_table(self) -> np.ndarray:
        e = np.zeros(N_LINK_CLASSES, np.float32)
        e[CLASS_LOCAL] = self.e_hop_local
        e[CLASS_RUCHE] = self.e_hop_ruche
        e[CLASS_WRAP] = self.e_hop_wrap
        e[CLASS_PORT] = self.e_hop_port
        e[CLASS_DIE] = self.e_hop_die
        return e


def link_cost_vectors(params: PerfParams, net):
    """Static per-link cost vectors for a Network backend.

    Returns ``(t_hop, e_hop)`` — two (num_links,) f32 arrays pricing each
    directed link by its class (``net.link_classes``): local neighbor
    links, ruche express channels, and torus wraparounds each at their own
    per-flit cycle/energy cost.
    """
    cls = np.asarray(net.link_classes)
    return (jnp.asarray(params.hop_cycle_table()[cls]),
            jnp.asarray(params.hop_energy_table()[cls]))


def flits_by_class(stats, net) -> dict:
    """Cumulative flit traversals per link class for an accumulated Stats.

    Returns ``{class_name: flits}`` over the classes that exist on ``net``
    (a link class with zero links on this wiring is omitted).  This is
    the per-level telemetry split of the hierarchical study: on the hier
    backend ``out["die"]`` is the die-to-die express traffic the
    die-local placements are built to minimize.
    """
    names = {CLASS_LOCAL: "local", CLASS_RUCHE: "ruche", CLASS_WRAP: "wrap",
             CLASS_PORT: "port", CLASS_DIE: "die"}
    cls = np.asarray(net.link_classes)
    flits = np.asarray(stats.flits_per_link, np.int64)
    return {names[c]: int(flits[cls == c].sum())
            for c in sorted(set(cls.tolist()))}


def die_crossing_frac(stats) -> float:
    """Fraction of fabric injections that crossed at least one die
    boundary (from ``Stats.die_crossings``; 0.0 on single-die fabrics
    and on runs with no traffic)."""
    hist = np.asarray(stats.die_crossings, np.int64)
    return float(hist[1:].sum()) / max(int(hist.sum()), 1)


def tile_compute_cycles(params: PerfParams, pops, pushes, spill_replays,
                        edges, updates, hbm_edges=None):
    """Per-tile compute cycles of one round (jnp, per-device shaped).

    ``hbm_edges`` — edge words streamed from an HBM-resident shard this
    round (``None`` on all-VMEM runs: the term is absent, not
    zero-multiplied, so pre-memspace cycle totals stay bit-stable)."""
    f = jnp.float32
    out = (pops.astype(f) * params.t_pop
           + pushes.astype(f) * params.t_push
           + spill_replays.astype(f) * params.t_spill
           + edges.astype(f) * params.t_scan
           + updates.astype(f) * params.t_fold)
    if hbm_edges is not None:
        out = out + hbm_edges.astype(f) * params.t_hbm
    return out


def leak_pj(params: PerfParams, T: int, cycles):
    """Static leakage over ``cycles`` on a T-tile grid — the single
    definition shared by the per-round accumulator, the reconciliation
    oracle, and fig10's ``leak_frac`` split."""
    return jnp.float32(T * params.e_leak_tile_cycle) * cycles


def round_energy_pj(params: PerfParams, T: int, edges_g, updates_g,
                    msgs_total, spills_total, link_flits_g, e_hop,
                    cycles_round, hbm_edges_g=None):
    """Global energy of one round, linear in the round's Stats increments
    (so totals reconcile with :func:`energy_from_totals`).  ``hbm_edges_g``
    prices the per-space split: ``None`` on all-VMEM runs (term absent,
    keeping pre-memspace energy totals bit-stable)."""
    f = jnp.float32
    out = (edges_g.astype(f) * params.e_scan
           + updates_g.astype(f) * params.e_fold
           + msgs_total.astype(f) * (params.e_push + params.e_pop)
           + spills_total.astype(f) * params.e_spill
           + (link_flits_g.astype(f) * e_hop).sum()
           + leak_pj(params, T, cycles_round))
    if hbm_edges_g is not None:
        out = out + hbm_edges_g.astype(f) * params.e_hbm
    return out


def migration_cost(params: PerfParams, words_intra: int,
                   words_cross: int) -> tuple[float, float]:
    """Price a migration plan (repro.place): modeled ``(cycles, pJ)``.

    ``words_intra``/``words_cross`` are 64-bit words moved between tiles
    of the same die vs across a die boundary.  Every word pays the paired
    SRAM read+write (``t_migrate``/``e_migrate``); cross-die words
    additionally pay one die-class hop — the dominant wire for an
    epoch-boundary bulk move, and the term the die-aware planner is
    trying to avoid.  The caller folds the result into ``Stats.cycles``/
    ``energy_pj`` and records it in ``Stats.migration_cycles``/
    ``migration_pj`` so ``energy_from_totals`` still reconciles.
    """
    words = float(words_intra) + float(words_cross)
    cycles = params.t_migrate * words + params.t_hop_die * float(words_cross)
    pj = params.e_migrate * words + params.e_hop_die * float(words_cross)
    return cycles, pj


def energy_from_totals(stats, params: PerfParams, net, T: int) -> float:
    """Recompute total energy from the final Stats counters (oracle for
    the accumulated ``Stats.energy_pj``; the tests assert they agree)."""
    _, e_hop = link_cost_vectors(params, net)
    edges = float(np.asarray(stats.edges_scanned))
    updates = float(np.asarray(stats.updates_applied))
    msgs = float(np.asarray(stats.msgs).sum())
    spills = float(np.asarray(stats.spills).sum())
    flits = np.asarray(stats.flits_per_link, np.float64)
    cycles = float(np.asarray(stats.cycles))
    hbm_edges = float(np.asarray(getattr(stats, "hbm_edges", 0)))
    migration_pj = float(np.asarray(getattr(stats, "migration_pj", 0)))
    return (edges * params.e_scan + updates * params.e_fold
            + msgs * (params.e_push + params.e_pop)
            + spills * params.e_spill
            + float((flits * np.asarray(e_hop, np.float64)).sum())
            + float(np.asarray(leak_pj(params, T, np.float32(cycles))))
            + hbm_edges * params.e_hbm
            + migration_pj)


def serving_metrics(queries: int, cycles: float, energy_pj: float,
                    edges: int, params: PerfParams = None) -> dict:
    """Throughput columns for a *serving* run (repro.serve): many queries
    sharing one makespan.  ``cycles``/``energy_pj`` are the batch clock and
    batch energy of the shared run (NOT per-lane sums — lanes
    time-multiplex the tiles, so per-lane cycles double-count the fixed
    round overhead), ``edges`` the total edges scanned across lanes.

    Returns queries/sec (``qps``), modeled joules per query
    (``j_per_query``), and the aggregate ``gteps`` on the same clock.
    """
    params = params or PerfParams()
    time_s = cycles / (params.f_ghz * 1e9)
    return {
        "cycles": int(round(cycles)),
        "time_model_s": round(time_s, 9),
        "qps": round(queries / time_s, 1) if time_s > 0 else 0.0,
        "gteps": round(edges / time_s / 1e9, 6) if time_s > 0 else 0.0,
        "energy_pj": round(energy_pj, 1),
        "j_per_query": round(energy_pj * 1e-12 / queries, 15)
        if queries else 0.0,
    }


def derived_metrics(stats, params: PerfParams = None, T: int = None,
                    trace=None) -> dict:
    """Time / throughput / energy columns from an accumulated Stats.

    ``params`` must be the run's ``cfg.perf`` whenever it was overridden —
    the clock and leak constants live here, not in Stats.  ``time_model_s``
    is modeled cycles over the tile clock; ``gteps`` is giga
    traversed-edges per modeled second (edges_scanned based, the paper's
    TEPS convention); ``pj_per_edge`` is the energy ladder metric.  With
    ``T`` given, the leakage share of the total (``leak_pj`` /
    ``leak_frac``) is split out using the same :func:`leak_pj` formula the
    accumulator priced it with.

    ``trace`` (a captured :class:`repro.trace.TraceBuf`) adds the flight
    recorder's ``util_mean`` / ``work_cov`` columns — ADDITIVE, like the
    HBM split: rows from untraced runs keep their exact historical shape.
    """
    params = params or PerfParams()
    cycles = float(np.asarray(stats.cycles))
    edges = float(np.asarray(stats.edges_scanned))
    energy = float(np.asarray(stats.energy_pj))
    time_s = cycles / (params.f_ghz * 1e9)
    out = {
        "cycles": int(round(cycles)),
        "time_model_s": round(time_s, 9),
        "gteps": round(edges / time_s / 1e9, 6) if time_s > 0 else 0.0,
        "energy_pj": round(energy, 1),
        "pj_per_edge": round(energy / edges, 3) if edges > 0 else 0.0,
    }
    if T is not None:
        lk = float(np.asarray(leak_pj(params, T, np.float32(cycles))))
        out["leak_pj"] = round(lk, 1)
        out["leak_frac"] = round(lk / energy, 3) if energy > 0 else 0.0
    # Per-space energy split (ADDITIVE — only on runs whose edge shard
    # streamed from HBM, so all-VMEM baseline rows stay byte-stable):
    # the streamed words priced at e_hbm, and their share of the total.
    hbm_edges = float(np.asarray(getattr(stats, "hbm_edges", 0)))
    if hbm_edges > 0:
        hbm_pj = hbm_edges * params.e_hbm
        out["hbm_pj"] = round(hbm_pj, 1)
        out["hbm_frac"] = round(hbm_pj / energy, 3) if energy > 0 else 0.0
        if edges > 0:
            out["pj_per_edge_hbm"] = round(hbm_pj / edges, 3)
            out["pj_per_edge_sram"] = round((energy - hbm_pj) / edges, 3)
    if trace is not None:
        from repro.trace.export import trace_metrics
        out.update(trace_metrics(trace))
    return out
