"""Parameterized cycle/energy performance model (first-order, 22nm-era).

Turns the engine's round/telemetry counters into modeled time, GTEPS and
joules — see :mod:`repro.perf.model` for the cost formula and caveats.
"""
from repro.perf.model import (CLASS_DIE, CLASS_LOCAL, CLASS_PORT,
                              CLASS_RUCHE, CLASS_WRAP, N_LINK_CLASSES,
                              PerfParams, derived_metrics,
                              die_crossing_frac, energy_from_totals,
                              flits_by_class, leak_pj, link_cost_vectors,
                              round_energy_pj, serving_metrics,
                              tile_compute_cycles)

__all__ = [
    "PerfParams", "derived_metrics", "die_crossing_frac",
    "energy_from_totals", "flits_by_class", "leak_pj", "link_cost_vectors",
    "round_energy_pj", "serving_metrics", "tile_compute_cycles",
    "CLASS_LOCAL", "CLASS_RUCHE", "CLASS_WRAP", "CLASS_PORT", "CLASS_DIE",
    "N_LINK_CLASSES",
]
