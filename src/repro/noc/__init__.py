"""Pluggable NoC subsystem: topology-aware routing for the Dalorex engine.

See :mod:`repro.noc.network` for the backend contract and
:mod:`repro.noc.topology` for the grid/link model.
"""
from repro.noc.network import (IdealAllToAll, Mesh2D, NetRouted, Ruche,
                               Torus2D, make_network)
from repro.noc.topology import (LOCAL_BWD, LOCAL_FWD, N_CHANNELS, RUCHE_BWD,
                                RUCHE_FWD, admit, grid_shape, line_usage)

__all__ = [
    "IdealAllToAll", "Mesh2D", "Torus2D", "Ruche", "NetRouted",
    "make_network", "grid_shape", "line_usage", "admit", "N_CHANNELS",
    "LOCAL_FWD", "LOCAL_BWD", "RUCHE_FWD", "RUCHE_BWD",
]
