"""Pluggable NoC subsystem: topology-aware routing for the Dalorex engine.

See :mod:`repro.noc.network` for the backend contract and
:mod:`repro.noc.topology` for the grid/link model.
"""
from repro.noc.network import (Hier2D, IdealAllToAll, Mesh2D, NetRouted,
                               Ruche, Torus2D, make_network)
from repro.noc.topology import (DIE_BWD, DIE_FWD, LOCAL_BWD, LOCAL_FWD,
                                N_CHANNELS, RUCHE_BWD, RUCHE_FWD, admit,
                                grid_shape, line_usage, tile_die_map)

__all__ = [
    "IdealAllToAll", "Mesh2D", "Torus2D", "Ruche", "Hier2D", "NetRouted",
    "make_network", "grid_shape", "line_usage", "admit", "tile_die_map",
    "N_CHANNELS", "LOCAL_FWD", "LOCAL_BWD", "RUCHE_FWD", "RUCHE_BWD",
    "DIE_FWD", "DIE_BWD",
]
