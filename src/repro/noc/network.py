"""Pluggable NoC backends for the Dalorex engine.

A :class:`Network` turns the engine's "route these messages to their
owners" step into an explicit fabric model.  All backends share the
engine-facing contract:

    route(comm, msgs, valid, capacity, dest_fn) -> NetRouted

where ``dest_fn`` decodes the destination tile from the *head flit* of each
message — the paper's headerless routing: every router re-derives the route
from message content, no metadata flits exist (Section III-E/F).  The
returned spill buffer holds messages that could not make progress this
round; because routes are content-derived, a spilled message can be
re-injected from *any* tile that holds it, so stranded-at-a-waypoint and
stranded-at-source replay through the same local-queue path.

Backends:

* :class:`IdealAllToAll` — the seed's semantics, extracted: one perfect
  crossbar round, contention only at endpoint slots (``capacity`` per
  destination).  Its "links" are the T ingress ports.
* :class:`Mesh2D` / :class:`Torus2D` / :class:`Ruche` — a (rows, cols)
  tile grid with dimension-ordered (X-then-Y) routing composed from two
  per-axis exchanges.  Each axis hop set is charged against **per-link**
  capacity (``link_cap`` flits per directed link per routing leg — an
  engine round has one leg per task channel of the running program) with
  the same spill-and-replay backpressure the endpoint queues use;
  telemetry counts every link traversal and the hop distance of every
  injection.
* :class:`Hier2D` — the multi-die composition: an ``ndies_y x ndies_x``
  array of intra-die meshes (or tori) whose lines are joined by inter-die
  express links (PIUMA-style die-of-dies).  Routing stays dimension-
  ordered; along each axis a cross-die journey completes its die-level
  express hops before the intra-die final approach.  At ``ndies = 1x1``
  it *is* the mesh/torus backend, link for link.

Link index space of the grid backends (``num_links = 8 * T``): an X block
``(rows, N_CHANNELS, cols)`` — the links of each row line — followed by a
Y block ``(cols, N_CHANNELS, rows)`` — the links of each column line —
both flattened.  Per-round occupancy of link ``l`` is the number of flits
that traversed it that round, summed over all tiles (``psum``).

Link-class contract: every backend exposes ``link_classes`` — a static
(num_links,) int32 vector attributing each directed link to one cost
class of :mod:`repro.noc.topology`, priced by :mod:`repro.perf`:

  ``LOCAL``  neighbor hop on a line           (1-tile wire)
  ``RUCHE``  ruche express channel            (``ruche_factor``-tile wire)
  ``WRAP``   torus ring-closing link          (longest wire on the line)
  ``PORT``   ideal-crossbar ingress port      (switch only, no wire)
  ``DIE``    hier die-to-die express link     (off-die wire + serdes)

Classes are a wiring property (what kind of wire the flit rides), not a
traffic property: links of an unused class simply never see flits (a mesh
carries RUCHE-class channel slots, a one-die hierarchy carries no
DIE-class traffic), which is what keeps telemetry and energy totals
bit-comparable across backends of identical geometry.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queues import histogram
from repro.core.routing import bin_by_owner, route_tasks
from repro.noc.topology import (CLASS_PORT, N_CHANNELS, admit, grid_shape,
                                line_link_classes, line_usage)


def _die_coord(pos, seg: int):
    """Die index of a 1-D position under segment length ``seg`` (0 = the
    axis is not segmented; everything is die 0)."""
    return pos // seg if seg > 0 else jnp.zeros_like(pos)


class NetRouted(NamedTuple):
    """One network round, plus this tile's telemetry contribution.

    recv / recv_valid / spill / spill_valid match ``core.routing.Routed``.

    sent:       () int32 — messages this tile *delivered to their owner*
                this round (for the grid backends, counted at the final
                leg, so a message spilled mid-route is counted once, on
                the round it completes — totals reconcile across backends).
    link_flits: (num_links,) int32 — flits this tile pushed onto each
                directed link this round (psum over tiles = occupancy).
    hop_hist:   (max_hops + 1,) int32 — histogram of the remaining hop
                distance of every fabric injection this round.  Exact per
                message while nothing spills mid-route; a message stranded
                at a waypoint is histogrammed again with its remaining
                distance when re-injected, so under heavy backpressure the
                histogram counts injection attempts, not unique messages.
    die_hist:   (max_die_crossings + 1,) int32 — histogram of the number
                of die boundaries each fabric injection still has to
                cross (X + Y).  Non-hierarchical backends put every
                injection in bin 0; same injection-attempt caveat as
                ``hop_hist`` (a replay from a waypoint re-buckets with
                its remaining crossings).
    """

    recv: jax.Array
    recv_valid: jax.Array
    spill: jax.Array
    spill_valid: jax.Array
    sent: jax.Array
    link_flits: jax.Array
    hop_hist: jax.Array
    die_hist: jax.Array


@dataclasses.dataclass(frozen=True)
class IdealAllToAll:
    """The seed's single-round perfect fabric (endpoint contention only)."""

    T: int
    name = "ideal"

    @property
    def num_links(self) -> int:
        return self.T  # ingress port of each tile

    @property
    def max_hops(self) -> int:
        return 1

    @property
    def max_die_crossings(self) -> int:
        return 0  # one die (one crossbar); die_hist is a single bin

    @property
    def link_classes(self) -> np.ndarray:
        """Crossbar ingress ports: switch energy per flit, no wire
        latency (endpoint serialization lives in the compute term)."""
        return np.full(self.num_links, CLASS_PORT, np.int32)

    def route(self, comm, msgs, valid, capacity: int, dest_fn) -> NetRouted:
        T = self.T
        dest = comm.run(lambda _me, m: jnp.clip(dest_fn(m), 0, T - 1), msgs)
        r = route_tasks(comm, msgs, valid, dest, capacity)

        def telemetry(_me, d, v, spill_v, n_sent):
            link = histogram(d, v & ~spill_v, T)  # per-ingress-port flits
            hop = jnp.stack([jnp.zeros((), jnp.int32), n_sent])
            return link, hop, n_sent[None]  # die_hist: everything in bin 0

        link, hop, die = comm.run(telemetry, dest, valid, r.spill_valid,
                                  r.sent)
        return NetRouted(r.recv, r.recv_valid, r.spill, r.spill_valid,
                         r.sent, link, hop, die)

    def pressure(self, me, link_flits):
        """Occupancy of this tile's ingress port last round."""
        return link_flits[me]

    def pressure_limit(self, cfg, route_caps=None) -> int:
        """TSU "fabric hot" threshold: the ideal crossbar has no links, so
        pressure only means endpoint-slot saturation — ingress near the
        combined per-destination slot bound of all the program's routing
        legs (``route_caps``; defaults to the classic two channels)."""
        if route_caps is None:
            route_caps = (cfg.cap_route_range, cfg.cap_route_update)
        return (3 * self.T * sum(route_caps)) // 4


@dataclasses.dataclass(frozen=True)
class _Grid2D:
    """Shared machinery of the physical (rows, cols) backends."""

    T: int
    rows: int
    cols: int
    link_cap: int = 0  # flits per directed link per round; 0 = unlimited
    name = "grid"
    wrap = False

    def __post_init__(self):
        if self.rows * self.cols != self.T:
            raise ValueError(f"{self.rows}x{self.cols} grid != {self.T} tiles")

    @property
    def ruche(self) -> int:
        return 0

    @property
    def die_x(self) -> int:
        """Die segment length of the X (row) lines; 0 = unsegmented."""
        return 0

    @property
    def die_y(self) -> int:
        """Die segment length of the Y (column) lines; 0 = unsegmented."""
        return 0

    @property
    def num_links(self) -> int:
        return 2 * N_CHANNELS * self.T  # X block + Y block

    @property
    def max_hops(self) -> int:
        if self.wrap:
            return max(self.cols // 2 + self.rows // 2, 1)
        return max(self.cols - 1 + self.rows - 1, 1)

    @property
    def max_die_crossings(self) -> int:
        return 0  # single-die grids: die_hist is one bin

    @property
    def link_classes(self) -> np.ndarray:
        """Per-link cost class in the link index space (X block then Y
        block) — ruche express channels, torus wraparounds and hier
        die-to-die links are priced differently from local neighbor hops
        by the perf model (see the module docstring's link-class
        contract)."""
        x = np.broadcast_to(line_link_classes(self.cols, self.wrap,
                                              self.die_x),
                            (self.rows, N_CHANNELS, self.cols))
        y = np.broadcast_to(line_link_classes(self.rows, self.wrap,
                                              self.die_y),
                            (self.cols, N_CHANNELS, self.rows))
        return np.concatenate([x.reshape(-1), y.reshape(-1)])

    def route(self, comm, msgs, valid, capacity: int, dest_fn) -> NetRouted:
        T, rows, cols = self.T, self.rows, self.cols
        wrap, ruche, cap = self.wrap, self.ruche, self.link_cap
        die_x, die_y = self.die_x, self.die_y
        n_hop = self.max_hops + 1
        n_die = self.max_die_crossings + 1
        tid = jnp.arange(T, dtype=jnp.int32)

        # Link capacity is global: tiles sharing a line admit in tile-major
        # FIFO order, each counting the (conservative) claims of every
        # earlier tile on that line — shared via one all_gather per leg.

        def x_geom(me, m, v):
            r_me, c_me = me // cols, me % cols
            d = jnp.clip(dest_fn(m), 0, T - 1)
            dr, dc = d // cols, d % cols
            hx, use_x = line_usage(jnp.broadcast_to(c_me, dc.shape), dc,
                                   cols, wrap, ruche, die_x)
            hy, _ = line_usage(jnp.broadcast_to(r_me, dr.shape), dr,
                               rows, wrap, ruche, die_y)
            cross = (jnp.abs(_die_coord(dc, die_x) - _die_coord(c_me, die_x))
                     + jnp.abs(_die_coord(dr, die_y)
                               - _die_coord(r_me, die_y)))
            claims = (use_x & v[:, None, None]).sum(0, dtype=jnp.int32)
            return dc, hx + hy, cross, use_x, claims

        def phase_x(me, m, v, dc, hops, cross, use_x, base):
            # X leg: ride the own-row line to the destination column; also
            # record the full X+Y hop distance and the remaining die
            # crossings of every admitted injection.
            r_me, c_me = me // cols, me % cols
            ok = admit(use_x, v, cap, base)
            buf, _, ep_spill, _ = bin_by_owner(m, v & ok, r_me * cols + dc,
                                               T, capacity)
            sent_mask = (v & ok) & ~ep_spill
            spill_v = v & ~sent_mask
            lx = jnp.zeros((rows, N_CHANNELS, cols), jnp.int32).at[r_me].add(
                (use_x & sent_mask[:, None, None]).sum(0, dtype=jnp.int32))
            hop = histogram(hops, sent_mask, n_hop)
            die = histogram(cross, sent_mask, n_die)
            return buf, m, spill_v, lx.reshape(-1), hop, die

        def x_base(me, all_claims):
            # standing claims of tiles earlier on my row line (tile-major)
            r_me, c_me = me // cols, me % cols
            earlier = (tid // cols == r_me) & (tid % cols < c_me)
            return jnp.where(earlier[:, None, None], all_claims, 0).sum(0)

        dc, hops, cross, use_x, claims_x = comm.run(x_geom, msgs, valid)
        if cap > 0:
            base_x = comm.run(x_base, comm.all_gather(claims_x))
        else:  # uncapped: admit() ignores claims — skip the exchange
            base_x = claims_x * 0
        bufx, spill1, spill1_v, lx, hop, die = comm.run(
            phase_x, msgs, valid, dc, hops, cross, use_x, base_x)
        mid = comm.a2a(bufx)

        def y_geom(me, rec):
            r_me, c_me = me // cols, me % cols
            v = rec[:, 0] >= 0
            d = jnp.clip(dest_fn(rec), 0, T - 1)
            dr = d // cols
            _, use_y = line_usage(jnp.broadcast_to(r_me, dr.shape), dr,
                                  rows, wrap, ruche, die_y)
            claims = (use_y & v[:, None, None]).sum(0, dtype=jnp.int32)
            return dr, use_y, claims

        def phase_y(me, rec, dr, use_y, base):
            # Y leg from the waypoint (src_row, dst_col) — which is this
            # tile for every message that arrived via phase X.
            r_me, c_me = me // cols, me % cols
            v = rec[:, 0] >= 0
            ok = admit(use_y, v, cap, base)
            buf, _, ep_spill, _ = bin_by_owner(rec, v & ok,
                                               dr * cols + c_me, T, capacity)
            sent_mask = (v & ok) & ~ep_spill
            spill_v = v & ~sent_mask
            ly = jnp.zeros((cols, N_CHANNELS, rows), jnp.int32).at[c_me].add(
                (use_y & sent_mask[:, None, None]).sum(0, dtype=jnp.int32))
            return (buf, rec, spill_v, sent_mask.sum(dtype=jnp.int32),
                    ly.reshape(-1))

        def y_base(me, all_claims):
            r_me, c_me = me // cols, me % cols
            earlier = (tid % cols == c_me) & (tid // cols < r_me)
            return jnp.where(earlier[:, None, None], all_claims, 0).sum(0)

        dr, use_y, claims_y = comm.run(y_geom, mid)
        if cap > 0:
            base_y = comm.run(y_base, comm.all_gather(claims_y))
        else:
            base_y = claims_y * 0
        # `sent` counts Y-leg completions, i.e. messages delivered to their
        # owner this round — so replays of mid-route spills are not
        # re-counted and grid totals reconcile with the ideal backend's.
        bufy, spill2, spill2_v, sent, ly = comm.run(
            phase_y, mid, dr, use_y, base_y)
        recv = comm.a2a(bufy)

        spill = jnp.concatenate([spill1, spill2], axis=-2)
        spill_v = jnp.concatenate([spill1_v, spill2_v], axis=-1)
        link = jnp.concatenate([lx, ly], axis=-1)
        return NetRouted(recv, recv[..., 0] >= 0, spill, spill_v, sent,
                         link, hop, die)

    def pressure_limit(self, cfg, route_caps=None) -> int:
        """TSU "fabric hot" threshold.  A link sees up to ``link_cap`` flits
        per leg and pressure sums every leg of the program's round (one per
        task channel), so hot = 3/4 of n_legs*link_cap; uncapped links fall
        back to the endpoint-saturation bound."""
        if route_caps is None:
            route_caps = (cfg.cap_route_range, cfg.cap_route_update)
        if self.link_cap > 0:
            return (3 * len(route_caps) * self.link_cap) // 4
        return (3 * self.T * sum(route_caps)) // 4

    def pressure(self, me, link_flits):
        """Max occupancy over the links this tile's traffic rides: its own
        row line (X block) and its own column line (Y block)."""
        r_me, c_me = me // self.cols, me % self.cols
        x = jax.lax.dynamic_slice(
            link_flits, (r_me * N_CHANNELS * self.cols,),
            (N_CHANNELS * self.cols,))
        y = jax.lax.dynamic_slice(
            link_flits,
            (N_CHANNELS * self.T + c_me * N_CHANNELS * self.rows,),
            (N_CHANNELS * self.rows,))
        return jnp.maximum(x.max(), y.max())


@dataclasses.dataclass(frozen=True)
class Mesh2D(_Grid2D):
    name = "mesh"
    wrap = False


@dataclasses.dataclass(frozen=True)
class Torus2D(_Grid2D):
    name = "torus"
    wrap = True


@dataclasses.dataclass(frozen=True)
class Ruche(_Grid2D):
    """Mesh plus long-range channels skipping ``ruche_factor`` tiles."""

    ruche_factor: int = 2
    name = "ruche"
    wrap = False

    @property
    def ruche(self) -> int:
        return max(self.ruche_factor, 2)


@dataclasses.dataclass(frozen=True)
class Hier2D(_Grid2D):
    """Multi-die hierarchical NoC: an ``ndies_y x ndies_x`` array of
    intra-die grids joined by DIE-class express links (module docstring).

    ``base`` selects the intra-die wiring: ``"mesh"`` (monotone lines) or
    ``"torus"`` (each die closes its own rings; the wrap shortcut applies
    to die-local traffic).  The global grid is still (rows, cols) with the
    same link index space as the flat backends, so ``ndies_x = ndies_y =
    1`` with a mesh base is **bit-identical** to :class:`Mesh2D` — same
    links, same routes, same telemetry — which is the equivalence anchor
    the tests pin down.  ``max_hops`` keeps the flat-mesh bound (a valid
    upper bound for every die shape, and the histogram shape that makes
    the ndies=1 Stats comparable).
    """

    ndies_x: int = 1
    ndies_y: int = 1
    base: str = "mesh"
    name = "hier"

    def __post_init__(self):
        super().__post_init__()
        if self.base not in ("mesh", "torus"):
            raise ValueError(f"hier base must be mesh|torus, got "
                             f"{self.base!r}")
        if (self.ndies_x <= 0 or self.ndies_y <= 0
                or self.cols % self.ndies_x or self.rows % self.ndies_y):
            raise ValueError(
                f"{self.rows}x{self.cols} grid not divisible into "
                f"{self.ndies_y}x{self.ndies_x} dies")

    @property
    def wrap(self) -> bool:
        return self.base == "torus"

    @property
    def die_x(self) -> int:
        return self.cols // self.ndies_x

    @property
    def die_y(self) -> int:
        return self.rows // self.ndies_y

    @property
    def max_hops(self) -> int:
        return max(self.cols - 1 + self.rows - 1, 1)

    @property
    def max_die_crossings(self) -> int:
        return self.ndies_x - 1 + self.ndies_y - 1


def make_network(cfg, T: int):
    """Build the backend selected by ``EngineConfig.noc`` for a T-tile run."""
    if cfg.noc == "ideal":
        return IdealAllToAll(T)
    rows, cols = grid_shape(T, cfg.noc_rows)
    if cfg.noc == "mesh":
        return Mesh2D(T, rows, cols, cfg.link_cap)
    if cfg.noc == "torus":
        return Torus2D(T, rows, cols, cfg.link_cap)
    if cfg.noc == "ruche":
        return Ruche(T, rows, cols, cfg.link_cap, cfg.ruche_factor)
    if cfg.noc == "hier":
        return Hier2D(T, rows, cols, cfg.link_cap,
                      ndies_x=cfg.ndies_x, ndies_y=cfg.ndies_y,
                      base=cfg.hier_base)
    raise ValueError(f"unknown noc backend {cfg.noc!r}")
