"""Tile-grid geometry for the physical NoC backends.

A grid of ``T = rows * cols`` tiles; tile ``t`` sits at ``(t // cols,
t % cols)``.  Dimension-ordered (X-then-Y) routing decomposes every route
into two 1-D journeys, so all link math lives in one helper,
:func:`line_usage`, parametric over the wiring of a single line of ``n``
tiles:

* mesh  — bidirectional neighbor links; travel is monotone toward the goal.
* torus — neighbor links plus wraparound; travel takes the shorter way.
* ruche — mesh plus long-range "ruche" channels that skip ``R`` tiles
  (HammerBlade-style); travel greedily rides ruche channels while the
  remaining distance allows, then finishes on local links.
* hier  — the line is segmented into ``n // die`` die segments of ``die``
  tiles each (PIUMA-style die-of-dies, one axis of it): local links exist
  only *within* a segment, and adjacent segments are joined by inter-die
  express links between their gateway tiles.  Cross-die travel rides
  local links to the source die's gateway, then one express hop per die
  boundary, then local links from the destination die's gateway — the
  die-level journey completes before the intra-die final approach.  With
  ``wrap=True`` each segment additionally closes its own ring (intra-die
  torus); the wrap shortcut applies to die-local travel only.

Directed links on a line of ``n`` tiles are indexed by their *source*
position in four channel classes (unused classes/positions simply never
see traffic):

  ``LOCAL_FWD``  i -> i+1   (torus: i -> (i+1) % n)
  ``LOCAL_BWD``  i -> i-1   (torus: i -> (i-1) % n)
  ``RUCHE_FWD``  i -> i+R   (hier: gateway i -> gateway i+die, DIE class)
  ``RUCHE_BWD``  i -> i-R   (hier: gateway i -> gateway i-die, DIE class)

The hier express links reuse the ruche channel slots (a line is either
ruched or segmented, never both): ``DIE_FWD`` links exist at the forward
gateways (segment-end positions, ``i % die == die-1``) and ``DIE_BWD`` at
the backward gateways (segment-start positions, ``i % die == 0``).

:func:`admit` implements the per-link analogue of the channel-queue
backpressure in ``core.routing``: a message is admitted into the fabric for
this round only if every directed link on its path has seen fewer than
``cap`` flits from earlier messages in FIFO order.  The count is
conservative — blocked messages also consume their claimed slots — which
keeps the admission decision a pure prefix-scan (vectorizable, identical
under vmap and shard_map).  The head of the FIFO always sails through, so
spill-and-replay makes progress every round and nothing is ever dropped.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

N_CHANNELS = 4
LOCAL_FWD, LOCAL_BWD, RUCHE_FWD, RUCHE_BWD = range(N_CHANNELS)
# the hier backend's inter-die express links live on the (otherwise
# unused) ruche channel slots
DIE_FWD, DIE_BWD = RUCHE_FWD, RUCHE_BWD

# Cost classes of directed links — a topology property (what kind of wire
# a flit rides), priced by the repro.perf model.  PORT is the ideal
# crossbar's ingress ports: no wire latency, switch energy only.  DIE is
# the hier backend's die-to-die express links: few of them, each driving
# an off-die wire (serdes crossing), so they are the priciest class.
CLASS_LOCAL, CLASS_RUCHE, CLASS_WRAP, CLASS_PORT, CLASS_DIE = range(5)
N_LINK_CLASSES = 5


def grid_shape(T: int, rows: int = 0) -> tuple[int, int]:
    """Factor ``T`` tiles into a (rows, cols) grid, near-square by default."""
    if rows <= 0:
        rows = max(int(math.isqrt(T)), 1)
        while T % rows:
            rows -= 1
    if T % rows:
        raise ValueError(f"rows={rows} does not divide T={T}")
    return rows, T // rows


def line_usage(a, b, n: int, wrap: bool = False, ruche: int = 0,
               die: int = 0):
    """Per-link usage of travel ``a -> b`` along one axis of the grid.

    a, b: (N,) int32 positions in [0, n).  Returns ``(hops, use)`` where
    ``hops`` is (N,) int32 and ``use`` is (N, N_CHANNELS, n) bool marking
    every directed link each message traverses.

    ``die`` > 0 segments the line into dies of ``die`` tiles (see module
    docstring): die-local travel behaves like a ``die``-tile mesh line
    (torus line when ``wrap``), cross-die travel is gateway -> express ->
    gateway with the express hops on the DIE_FWD/DIE_BWD channel slots.
    ``die in (0, n)`` degenerates to the unsegmented wirings, so a
    one-die hierarchy is *exactly* a mesh/torus line.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    ln = jnp.arange(n, dtype=jnp.int32)[None, :]
    a_, b_ = a[:, None], b[:, None]
    zero = jnp.zeros(a_.shape[:1] + (n,), bool)
    if 0 < die < n:
        assert n % die == 0, (n, die)
        m = die
        da_, db_ = a_ // m, b_ // m
        oa_ = a_ % m
        ln_d, ln_o = ln // m, ln % m
        same = (a // m) == (b // m)
        # die-local travel: a ``m``-tile mesh line (torus line if wrap)
        if wrap:
            dmod = (b - a) % m
            fwd_s = dmod <= m // 2
            hops_s = jnp.where(fwd_s, dmod, m - dmod)
            seg = ln_d == da_
            use_f_s = ((same & fwd_s)[:, None] & seg
                       & (((ln_o - oa_) % m) < dmod[:, None]))
            use_b_s = ((same & ~fwd_s)[:, None] & seg
                       & (((oa_ - ln_o) % m) < (m - dmod)[:, None]))
        else:
            fwd_s = (b - a) >= 0
            hops_s = jnp.abs(b - a)
            use_f_s = (same & fwd_s)[:, None] & (ln >= a_) & (ln < b_)
            use_b_s = (same & ~fwd_s)[:, None] & (ln <= a_) & (ln > b_)
        # cross-die: monotone to the own gateway, one express hop per die
        # boundary, monotone from the destination gateway
        cf = (b // m) > (a // m)
        cb = ~same & ~cf
        hops_cf = (m - 1 - a % m) + (b // m - a // m) + (m - 1 - b % m)
        hops_cb = (a % m) + (a // m - b // m) + (b % m)
        cf_, cb_ = cf[:, None], cb[:, None]
        use_f = (use_f_s
                 | (cf_ & (ln_d == da_) & (ln >= a_) & (ln_o < m - 1))
                 | (cb_ & (ln_d == db_) & (ln < b_)))
        use_b = (use_b_s
                 | (cf_ & (ln_d == db_) & (ln > b_))
                 | (cb_ & (ln_d == da_) & (ln <= a_) & (ln_o > 0)))
        use_df = cf_ & (ln_o == m - 1) & (ln_d >= da_) & (ln_d < db_)
        use_db = cb_ & (ln_o == 0) & (ln_d <= da_) & (ln_d > db_)
        hops = jnp.where(same, hops_s, jnp.where(cf, hops_cf, hops_cb))
        return hops, jnp.stack([use_f, use_b, use_df, use_db], axis=1)
    if wrap:
        d = (b - a) % n
        fwd = d <= n // 2
        hops = jnp.where(fwd, d, n - d)
        use_f = fwd[:, None] & (((ln - a_) % n) < d[:, None])
        use_b = (~fwd)[:, None] & (((a_ - ln) % n) < (n - d)[:, None])
        use_rf = use_rb = zero
    elif ruche > 1:
        dist = b - a
        fwd = dist >= 0
        ad = jnp.abs(dist)
        k, rem = ad // ruche, ad % ruche
        hops = k + rem
        kr = (k * ruche)[:, None]
        use_rf = (fwd[:, None] & (ln >= a_) & (ln < a_ + kr)
                  & ((ln - a_) % ruche == 0))
        use_f = fwd[:, None] & (ln >= a_ + kr) & (ln < b_)
        use_rb = ((~fwd)[:, None] & (ln <= a_) & (ln > a_ - kr)
                  & ((a_ - ln) % ruche == 0))
        use_b = (~fwd)[:, None] & (ln <= a_ - kr) & (ln > b_)
    else:
        dist = b - a
        fwd = dist >= 0
        hops = jnp.abs(dist)
        use_f = fwd[:, None] & (ln >= a_) & (ln < b_)
        use_b = (~fwd)[:, None] & (ln <= a_) & (ln > b_)
        use_rf = use_rb = zero
    return hops, jnp.stack([use_f, use_b, use_rf, use_rb], axis=1)


def line_link_classes(n: int, wrap: bool = False, die: int = 0) -> np.ndarray:
    """Cost-class id of every directed link on one line of ``n`` tiles.

    Returns (N_CHANNELS, n) int32 in the perf model's class space: the
    RUCHE_FWD/RUCHE_BWD channels are express links (CLASS_RUCHE — they
    drive ``ruche_factor``-long wires), or CLASS_DIE inter-die express
    links when the line is segmented (``die`` > 0); on a torus line the
    two links that close each ring — source position ``n-1`` forward and
    ``0`` backward per segment, exactly the links :func:`line_usage`
    charges for a wraparound traversal — are CLASS_WRAP (the longest wire
    on the line); everything else is a CLASS_LOCAL neighbor hop.  Static
    numpy: the engine bakes the resulting per-link cost vectors into the
    compiled round.
    """
    cls = np.full((N_CHANNELS, n), CLASS_LOCAL, np.int32)
    express = CLASS_DIE if 0 < die < n else CLASS_RUCHE
    cls[RUCHE_FWD] = express
    cls[RUCHE_BWD] = express
    if wrap:
        m = die if 0 < die < n else n
        cls[LOCAL_FWD, m - 1::m] = CLASS_WRAP
        cls[LOCAL_BWD, 0::m] = CLASS_WRAP
    return cls


def tile_die_map(T: int, rows: int = 0, ndies_y: int = 1,
                 ndies_x: int = 1) -> np.ndarray:
    """(T,) int64 die id of every tile of a (rows, cols) grid cut into an
    ``ndies_y x ndies_x`` array of equal dies (row-major die numbering).

    This is the placement-side view of the hier backend's geometry: the
    ``*_dielocal`` schemes in :mod:`repro.core.distribution` consume it to
    keep graph partitions die-resident.  ``rows=0`` uses the same
    near-square factorization as :func:`grid_shape`, so placement and
    fabric agree by default.
    """
    rows, cols = grid_shape(T, rows)
    if rows % ndies_y or cols % ndies_x:
        raise ValueError(
            f"{rows}x{cols} grid not divisible into {ndies_y}x{ndies_x} dies")
    t = np.arange(T, dtype=np.int64)
    r, c = t // cols, t % cols
    return (r // (rows // ndies_y)) * ndies_x + c // (cols // ndies_x)


def admit(use, valid, cap: int, base=None):
    """FIFO per-link admission under a per-round link capacity.

    use: (N, C, L) bool link usage per message; valid: (N,) bool.  Message i
    is admitted iff every link it uses has < ``cap`` flits claimed by earlier
    valid messages (claims are counted whether or not those messages were
    themselves admitted — see module docstring).  ``base`` (C, L) int32 adds
    claims already standing against each link — the grid backends pass the
    summed claims of tiles earlier in the global admission order, so the
    capacity is enforced *per link*, not per injector.  ``cap <= 0``
    disables the limit (infinite links; telemetry still records occupancy).
    """
    if cap <= 0:
        return valid
    u = (use & valid[:, None, None]).astype(jnp.int32)
    prior = jnp.cumsum(u, axis=0) - u  # exclusive prefix per link
    if base is not None:
        prior = prior + base[None]
    worst = jnp.where(use, prior, 0).max(axis=(1, 2))
    return valid & (worst < cap)
