"""Tile-grid geometry for the physical NoC backends.

A grid of ``T = rows * cols`` tiles; tile ``t`` sits at ``(t // cols,
t % cols)``.  Dimension-ordered (X-then-Y) routing decomposes every route
into two 1-D journeys, so all link math lives in one helper,
:func:`line_usage`, parametric over the wiring of a single line of ``n``
tiles:

* mesh  — bidirectional neighbor links; travel is monotone toward the goal.
* torus — neighbor links plus wraparound; travel takes the shorter way.
* ruche — mesh plus long-range "ruche" channels that skip ``R`` tiles
  (HammerBlade-style); travel greedily rides ruche channels while the
  remaining distance allows, then finishes on local links.

Directed links on a line of ``n`` tiles are indexed by their *source*
position in four channel classes (unused classes/positions simply never
see traffic):

  ``LOCAL_FWD``  i -> i+1   (torus: i -> (i+1) % n)
  ``LOCAL_BWD``  i -> i-1   (torus: i -> (i-1) % n)
  ``RUCHE_FWD``  i -> i+R
  ``RUCHE_BWD``  i -> i-R

:func:`admit` implements the per-link analogue of the channel-queue
backpressure in ``core.routing``: a message is admitted into the fabric for
this round only if every directed link on its path has seen fewer than
``cap`` flits from earlier messages in FIFO order.  The count is
conservative — blocked messages also consume their claimed slots — which
keeps the admission decision a pure prefix-scan (vectorizable, identical
under vmap and shard_map).  The head of the FIFO always sails through, so
spill-and-replay makes progress every round and nothing is ever dropped.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

N_CHANNELS = 4
LOCAL_FWD, LOCAL_BWD, RUCHE_FWD, RUCHE_BWD = range(N_CHANNELS)

# Cost classes of directed links — a topology property (what kind of wire
# a flit rides), priced by the repro.perf model.  PORT is the ideal
# crossbar's ingress ports: no wire latency, switch energy only.
CLASS_LOCAL, CLASS_RUCHE, CLASS_WRAP, CLASS_PORT = 0, 1, 2, 3
N_LINK_CLASSES = 4


def grid_shape(T: int, rows: int = 0) -> tuple[int, int]:
    """Factor ``T`` tiles into a (rows, cols) grid, near-square by default."""
    if rows <= 0:
        rows = max(int(math.isqrt(T)), 1)
        while T % rows:
            rows -= 1
    if T % rows:
        raise ValueError(f"rows={rows} does not divide T={T}")
    return rows, T // rows


def line_usage(a, b, n: int, wrap: bool = False, ruche: int = 0):
    """Per-link usage of travel ``a -> b`` along one axis of the grid.

    a, b: (N,) int32 positions in [0, n).  Returns ``(hops, use)`` where
    ``hops`` is (N,) int32 and ``use`` is (N, N_CHANNELS, n) bool marking
    every directed link each message traverses.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    ln = jnp.arange(n, dtype=jnp.int32)[None, :]
    a_, b_ = a[:, None], b[:, None]
    zero = jnp.zeros(a_.shape[:1] + (n,), bool)
    if wrap:
        d = (b - a) % n
        fwd = d <= n // 2
        hops = jnp.where(fwd, d, n - d)
        use_f = fwd[:, None] & (((ln - a_) % n) < d[:, None])
        use_b = (~fwd)[:, None] & (((a_ - ln) % n) < (n - d)[:, None])
        use_rf = use_rb = zero
    elif ruche > 1:
        dist = b - a
        fwd = dist >= 0
        ad = jnp.abs(dist)
        k, rem = ad // ruche, ad % ruche
        hops = k + rem
        kr = (k * ruche)[:, None]
        use_rf = (fwd[:, None] & (ln >= a_) & (ln < a_ + kr)
                  & ((ln - a_) % ruche == 0))
        use_f = fwd[:, None] & (ln >= a_ + kr) & (ln < b_)
        use_rb = ((~fwd)[:, None] & (ln <= a_) & (ln > a_ - kr)
                  & ((a_ - ln) % ruche == 0))
        use_b = (~fwd)[:, None] & (ln <= a_ - kr) & (ln > b_)
    else:
        dist = b - a
        fwd = dist >= 0
        hops = jnp.abs(dist)
        use_f = fwd[:, None] & (ln >= a_) & (ln < b_)
        use_b = (~fwd)[:, None] & (ln <= a_) & (ln > b_)
        use_rf = use_rb = zero
    return hops, jnp.stack([use_f, use_b, use_rf, use_rb], axis=1)


def line_link_classes(n: int, wrap: bool = False) -> np.ndarray:
    """Cost-class id of every directed link on one line of ``n`` tiles.

    Returns (N_CHANNELS, n) int32 in the perf model's class space: the
    RUCHE_FWD/RUCHE_BWD channels are express links (CLASS_RUCHE — they
    drive ``ruche_factor``-long wires); on a torus line the two links that
    close the ring — source position ``n-1`` forward and ``0`` backward,
    exactly the links :func:`line_usage` charges for a wraparound
    traversal — are CLASS_WRAP (the longest wire on the line); everything
    else is a CLASS_LOCAL neighbor hop.  Static numpy: the engine bakes
    the resulting per-link cost vectors into the compiled round.
    """
    cls = np.full((N_CHANNELS, n), CLASS_LOCAL, np.int32)
    cls[RUCHE_FWD] = CLASS_RUCHE
    cls[RUCHE_BWD] = CLASS_RUCHE
    if wrap:
        cls[LOCAL_FWD, n - 1] = CLASS_WRAP
        cls[LOCAL_BWD, 0] = CLASS_WRAP
    return cls


def admit(use, valid, cap: int, base=None):
    """FIFO per-link admission under a per-round link capacity.

    use: (N, C, L) bool link usage per message; valid: (N,) bool.  Message i
    is admitted iff every link it uses has < ``cap`` flits claimed by earlier
    valid messages (claims are counted whether or not those messages were
    themselves admitted — see module docstring).  ``base`` (C, L) int32 adds
    claims already standing against each link — the grid backends pass the
    summed claims of tiles earlier in the global admission order, so the
    capacity is enforced *per link*, not per injector.  ``cap <= 0``
    disables the limit (infinite links; telemetry still records occupancy).
    """
    if cap <= 0:
        return valid
    u = (use & valid[:, None, None]).astype(jnp.int32)
    prior = jnp.cumsum(u, axis=0) - u  # exclusive prefix per link
    if base is not None:
        prior = prior + base[None]
    worst = jnp.where(use, prior, 0).max(axis=(1, 2))
    return valid & (worst < cap)
