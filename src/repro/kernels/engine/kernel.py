"""Pallas tile-grid execution backend for the Dalorex engine round.

One grid program = one Dalorex tile.  The engine's per-round hot path —
the queue->scan->route->fold legs of ``engine.make_round`` — is re-expressed
here as four Pallas kernels whose *block* is the tile's VMEM-resident
vertex/edge shard.  Under ``LocalComm`` the engine vmaps per-tile stages,
and Pallas's batching rule turns the vmapped tile axis into a leading grid
dimension — literally one grid program per tile; under ``AxisComm``
(shard_map SPMD) each device *is* one tile and the kernels run gridless on
its shard.  The query-lane axis of ``repro.serve`` (the round vmapped over
``(B,)`` concurrent traversals) rides the same batching rule as one more
leading grid dimension — a ``(B, T)`` grid of programs, no kernel changes.  See DESIGN.md "Pallas backend" for the tile-grid mapping, the
per-tile VMEM budget, and the TPU (non-interpret) caveats.

The four kernels mirror the paper's per-tile pipeline (Section III):

* :func:`frontier_pop` — the fused T4 pop: take the first ``k`` set bits of
  the frontier bitmap and clear them, compacting the popped vertex indices
  with a cumsum-rank scatter (no sort) — the task-queue head of Listing 1.
* :func:`queue_push_pop` — one fused circular-FIFO turn: append this
  round's fresh tasks and pop the TSU budget off the front in a single
  kernel, replacing the engine's ``queue_push`` + ``queue_take_front``
  pair (two argsort compactions) with one scatter + one shift.
* :func:`edge_scan_gather` — the T2 leg: segment gather over the popped
  ``(start, stop)`` ranges out of the tile's edge shard.  The head flits of
  the received messages index straight into local memory — the same
  "the index IS the route" idiom as ``kernels/spmv``'s scalar-prefetched
  block-ELL x-gather, applied to the ragged CSR segments.
* :func:`fold_scatter` — the T3 leg: drain a delivered CQ buffer and
  scatter-min / scatter-add it into the tile's owned slice of the value
  array.  Atomic-free by construction: every write targets the tile's own
  shard (the paper's ownership argument, Section III-A).

All kernels default to ``interpret=True`` so CPU CI executes the very same
kernel bodies the TPU path compiles, and every kernel is **bit-identical**
to its XLA twin in ``core/program.py`` / ``core/queues.py`` (the backend
equivalence contract ``tests/test_backend_pallas.py`` enforces).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# float32 max as a python float (pallas kernels cannot capture traced
# consts); must equal core.program.INF so the fold's neutral element is the
# same "unreached" sentinel the XLA legs use.
_INF = 3.4028234663852886e38


# --------------------------------------------------------------------------
# T4: fused frontier pop (take_first_k as one kernel).
# --------------------------------------------------------------------------

def _frontier_pop_kernel(k_ref, mask_ref, idx_ref, valid_ref, rem_ref):
    mask = mask_ref[...]
    k = k_ref[0]
    n = mask.shape[0]
    k_max = idx_ref.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    mi = mask.astype(jnp.int32)
    rank = jnp.cumsum(mi) - mi            # 0-based rank among set bits
    take = mask & (rank < k)
    # rank < k <= k_max for every taken bit, so the scatter stays in-bounds;
    # slot k_max is the trash slot for the rest.
    slot = jnp.where(take, rank, jnp.int32(k_max))
    idx = jnp.zeros((k_max + 1,), jnp.int32).at[slot].set(ar)
    idx_ref[...] = idx[:k_max]
    n_take = take.sum(dtype=jnp.int32)
    valid_ref[...] = jnp.arange(k_max, dtype=jnp.int32) < n_take
    rem_ref[...] = mask & ~take


@functools.partial(jax.jit, static_argnames=("k_max", "interpret"))
def frontier_pop(mask: jax.Array, k: jax.Array, k_max: int,
                 interpret: bool = True):
    """Pop the first ``min(k, popcount)`` set bits of the tile's frontier
    bitmap, FIFO by position — the Pallas twin of
    :func:`repro.core.program.take_first_k`.

    mask: (n,) bool; k: () int32 dynamic budget (<= k_max).
    Returns (idx (k_max,) i32, valid (k_max,) bool, cleared_mask (n,) bool).
    Invalid slots of ``idx`` hold 0 (the XLA twin holds unpopped positions
    there); both are don't-cares masked by ``valid`` everywhere downstream.
    """
    n = mask.shape[0]
    return pl.pallas_call(
        _frontier_pop_kernel,
        out_shape=(jax.ShapeDtypeStruct((k_max,), jnp.int32),
                   jax.ShapeDtypeStruct((k_max,), jnp.bool_),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)),
        interpret=interpret,
    )(jnp.asarray(k, jnp.int32).reshape(1), mask)


# --------------------------------------------------------------------------
# Fused circular-FIFO turn: push fresh tasks, pop the TSU budget.
# --------------------------------------------------------------------------

def _queue_push_pop_kernel(n_ref, data_ref, count_ref, rows_ref, pvalid_ref,
                           taken_ref, tvalid_ref, ndata_ref, ncount_ref,
                           drops_ref):
    data = data_ref[...]
    count = count_ref[0]
    rows = rows_ref[...]
    pvalid = pvalid_ref[...]
    cap, w = data.shape
    max_n = taken_ref.shape[0]
    # --- push: append valid fresh rows at the tail (cumsum slot claim) ---
    mi = pvalid.astype(jnp.int32)
    offs = count + jnp.cumsum(mi) - mi
    ok = pvalid & (offs < cap)
    slot = jnp.where(ok, offs, jnp.int32(cap))  # cap = trash slot
    ext = jnp.concatenate([data, jnp.zeros((1, w), jnp.int32)], axis=0)
    data2 = ext.at[slot].set(rows)[:cap]
    n_push = ok.sum(dtype=jnp.int32)
    count2 = count + n_push
    drops_ref[0] = mi.sum() - n_push
    # --- pop: the front min(n, count2) rows, then shift the queue left ---
    n_pop = jnp.minimum(n_ref[0], count2)
    taken_ref[...] = data2[:max_n]
    tvalid_ref[...] = jnp.arange(max_n, dtype=jnp.int32) < n_pop
    src = jnp.minimum(jnp.arange(cap, dtype=jnp.int32) + n_pop, cap - 1)
    ndata_ref[...] = data2[src]
    ncount_ref[0] = count2 - n_pop


@functools.partial(jax.jit, static_argnames=("max_n", "interpret"))
def queue_push_pop(data: jax.Array, count: jax.Array, rows: jax.Array,
                   valid: jax.Array, n: jax.Array, max_n: int,
                   interpret: bool = True):
    """One fused FIFO turn: ``queue_push(rows[valid])`` then
    ``queue_take_front(min(n, count'))`` in a single kernel.

    data: (cap, w) i32 queue buffer whose first ``count`` rows are live;
    rows/valid: the fresh tasks; n: () i32 dynamic pop budget (<= max_n).
    Returns (taken (max_n, w), taken_valid (max_n,), new_data (cap, w),
    new_count () i32, drops () i32).  Live rows (< new_count) and the taken
    buffer are bit-identical to the two-call XLA path; rows at or beyond
    the live count are unobservable garbage in both backends.
    """
    cap = data.shape[0]
    assert max_n <= cap, f"pop budget bound {max_n} > queue capacity {cap}"
    taken, tvalid, ndata, ncount, drops = pl.pallas_call(
        _queue_push_pop_kernel,
        out_shape=(jax.ShapeDtypeStruct((max_n, data.shape[1]), jnp.int32),
                   jax.ShapeDtypeStruct((max_n,), jnp.bool_),
                   jax.ShapeDtypeStruct(data.shape, jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(jnp.asarray(n, jnp.int32).reshape(1), data,
      jnp.asarray(count, jnp.int32).reshape(1), rows, valid)
    return taken, tvalid, ndata, ncount[0], drops[0]


# --------------------------------------------------------------------------
# T2: segment gather over the tile's edge shard.
# --------------------------------------------------------------------------

def _edge_scan_kernel(edge_dst_ref, edge_val_ref, start_ref, stop_ref,
                      rv_ref, nb_ref, w_ref, jvalid_ref, *, e_chunk):
    start = start_ref[...]
    stop = stop_ref[...]
    rv = rv_ref[...]
    max_t2 = nb_ref.shape[1]
    length = jnp.where(rv, stop - start, 0)
    local0 = jnp.where(rv, start % e_chunk, 0)
    j = jnp.arange(max_t2, dtype=jnp.int32)[None, :]
    eidx = local0[:, None] + j                    # (R, MAX_T2)
    jvalid = rv[:, None] & (j < length[:, None])
    eidx_c = jnp.minimum(eidx, e_chunk - 1)
    nb = edge_dst_ref[...][eidx_c]
    nb_ref[...] = nb
    w_ref[...] = edge_val_ref[...][eidx_c]
    jvalid_ref[...] = jvalid & (nb >= 0)


@functools.partial(jax.jit, static_argnames=("max_t2", "interpret"))
def edge_scan_gather(edge_dst: jax.Array, edge_val: jax.Array,
                     start: jax.Array, stop: jax.Array, rv: jax.Array,
                     max_t2: int, interpret: bool = True):
    """The T2 segment gather: for each delivered range message, read its
    ``[start, stop)`` slice (bounded by MAX_T2 upstream) out of the tile's
    VMEM-resident edge shard.

    The head flit is the address — the received global edge index maps to a
    local offset (``start % e_chunk``) and indexes straight into the shard,
    the ragged-CSR analogue of ``kernels/spmv``'s scalar-prefetched column
    index.  Returns (nb (R, max_t2) i32, w (R, max_t2) f32,
    jvalid (R, max_t2) bool), bit-identical to the inline XLA gather in
    :func:`repro.core.program.edge_scan`.
    """
    e_chunk = edge_dst.shape[0]
    r = start.shape[0]
    return pl.pallas_call(
        functools.partial(_edge_scan_kernel, e_chunk=e_chunk),
        out_shape=(jax.ShapeDtypeStruct((r, max_t2), jnp.int32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.float32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.bool_)),
        interpret=interpret,
    )(edge_dst, edge_val, start, stop, rv)


# --------------------------------------------------------------------------
# T3: CQ drain + owner-local scatter fold.
# --------------------------------------------------------------------------

def _fold_scatter_kernel(target_ref, lidx_ref, vals_ref, valid_ref, out_ref,
                         *, op):
    target = target_ref[...]
    lidx = lidx_ref[...]
    vals = vals_ref[...]
    valid = valid_ref[...]
    v_chunk = target.shape[0]
    neutral = _INF if op == "min" else 0.0
    # lidx holds v_chunk (the trash slot) for invalid rows already; the
    # extended buffer absorbs them without a branch.
    ext = jnp.concatenate(
        [target, jnp.full((1,), neutral, jnp.float32)])
    masked = jnp.where(valid, vals, jnp.float32(neutral))
    if op == "min":
        ext = ext.at[lidx].min(masked)
    else:
        ext = ext.at[lidx].add(masked)
    out_ref[...] = ext[:v_chunk]


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def fold_scatter(target: jax.Array, lidx: jax.Array, vals: jax.Array,
                 valid: jax.Array, op: str = "min", interpret: bool = True):
    """The T3 fold: drain a delivered CQ buffer into the tile's owned
    ``(v_chunk,)`` slice — scatter-min for relaxations, scatter-add for
    accumulations.  Atomic-free: all writes land in this tile's own shard
    (Section III-A), so the kernel needs no synchronization.

    target: (v_chunk,) f32; lidx: (R,) i32 local indices with ``v_chunk``
    as the trash slot for invalid rows; vals/valid: the drained payloads.
    Bit-identical to the XLA ``ext.at[lidx].min/add`` twin in
    :func:`repro.core.program.scatter_fold`.
    """
    assert op in ("min", "add"), op
    return pl.pallas_call(
        functools.partial(_fold_scatter_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct(target.shape, jnp.float32),
        interpret=interpret,
    )(target, lidx, vals, valid)
