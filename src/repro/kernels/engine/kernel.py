"""Pallas tile-grid execution backend for the Dalorex engine round.

One grid program = one Dalorex tile.  The engine's per-round hot path —
the queue->scan->route->fold legs of ``engine.make_round`` — is re-expressed
here as Pallas kernels whose *block* is the tile's VMEM-resident
vertex/edge shard.  Under ``LocalComm`` the engine vmaps per-tile stages,
and Pallas's batching rule turns the vmapped tile axis into a leading grid
dimension — literally one grid program per tile; under ``AxisComm``
(shard_map SPMD) each device *is* one tile and the kernels run gridless on
its shard.  The query-lane axis of ``repro.serve`` (the round vmapped over
``(B,)`` concurrent traversals) rides the same batching rule as one more
leading grid dimension — a ``(B, T)`` grid of programs, no kernel changes.
See DESIGN.md "Pallas backend" for the tile-grid mapping, the per-tile
VMEM budget, and the TPU (non-interpret) caveats.

Two granularities share one set of *pure bodies* (:func:`frontier_take`,
:func:`fifo_turn`, :func:`queue_append`, :func:`segment_gather`,
:func:`scatter_body` — plain jnp value->value functions):

* **Standalone kernels** — :func:`frontier_pop`, :func:`queue_push_pop`,
  :func:`edge_scan_gather`, :func:`fold_scatter` wrap one body each in its
  own ``pallas_call`` (PR4's four-launch leg, kept as the
  ``pallas_fuse=False`` legacy path and for the kernel-twin tests).
* **The fused leg** — :func:`fused_leg_call` runs a *whole* engine channel
  leg (frontier-pop -> FIFO turn -> transform -> spill re-queue ->
  split-remainder re-push -> segment-gather -> scatter-fold, whatever the
  stage composes) as ONE ``pallas_call``: the per-tile stage function
  itself becomes the kernel body, every intermediate lives in
  VMEM-resident registers/scratch of that single launch, and the XLA glue
  that used to run *between* kernels (the mid-round spill re-queue and the
  split-remainder re-push) is absorbed into the same body via the pure
  queue bodies.  ``Ctx.fused`` routes the building blocks of
  ``core/program.py`` to the pure bodies so a fused leg never nests a
  ``pallas_call``.  The fold stays the in-kernel ``.at[]`` scatter idiom
  of ``kernels/scatter_update`` (owner-local, atomic-free) rather than the
  one-hot matmul alternative — bit-identical to XLA in interpret mode; on
  a real TPU a scatter-add drains in-order per row, so add folds may drift
  by the last ulp vs XLA's unspecified reduction order (DESIGN.md).

Every ``pallas_call`` dispatch is *counted*: the public wrappers call
:func:`repro.kernels.engine.launches.record` at trace time, the engine
brackets its round with :func:`..launches.tally`, and the per-round total
surfaces as ``Stats.launches`` (fig11's ``launches_per_round`` column —
one launch per leg fused, vs 4+ standalone launches plus XLA glue before).

All kernels default to ``interpret=True`` so CPU CI executes the very same
kernel bodies the TPU path compiles, and every kernel is **bit-identical**
to its XLA twin in ``core/program.py`` / ``core/queues.py`` (the backend
equivalence contract ``tests/test_backend_pallas.py`` +
``tests/test_fused_leg.py`` enforce).  ``pad_lanes=True`` additionally
pads every fused-leg operand block out to the TPU's (8, 128)
sublane x lane f32 tile (sliced back to logical shape inside the body), so
the same harness lands aligned blocks when ``pallas_interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.engine.launches import record

# float32 max as a python float (pallas kernels cannot capture traced
# consts); must equal core.program.INF so the fold's neutral element is the
# same "unreached" sentinel the XLA legs use.
_INF = 3.4028234663852886e38


# ==========================================================================
# Pure bodies: value -> value, shared by the standalone kernels and the
# fused leg (Ctx.fused routes core/program.py's building blocks here).
# ==========================================================================

def frontier_take(mask: jax.Array, k: jax.Array, k_max: int):
    """Body of :func:`frontier_pop`: first ``min(k, popcount)`` set bits,
    FIFO by position, compacted with a cumsum-rank scatter (no sort).
    Returns (idx (k_max,) i32, valid (k_max,) bool, cleared_mask)."""
    n = mask.shape[0]
    ar = jnp.arange(n, dtype=jnp.int32)
    mi = mask.astype(jnp.int32)
    rank = jnp.cumsum(mi) - mi            # 0-based rank among set bits
    take = mask & (rank < k)
    # rank < k <= k_max for every taken bit, so the scatter stays in-bounds;
    # slot k_max is the trash slot for the rest.
    slot = jnp.where(take, rank, jnp.int32(k_max))
    idx = jnp.zeros((k_max + 1,), jnp.int32).at[slot].set(ar)
    n_take = take.sum(dtype=jnp.int32)
    valid = jnp.arange(k_max, dtype=jnp.int32) < n_take
    return idx[:k_max], valid, mask & ~take


def fifo_turn(data: jax.Array, count: jax.Array, rows: jax.Array,
              valid: jax.Array, n: jax.Array, max_n: int):
    """Body of :func:`queue_push_pop`: one circular-FIFO turn — append the
    valid fresh rows at the tail (cumsum slot claim, overflow -> drops),
    then pop ``min(n, count')`` off the front with a single shift.

    Returns (taken (min(max_n, cap), w), taken_valid, new_data (cap, w),
    new_count () i32, drops () i32).  The taken buffer is clamped to the
    capacity exactly like the XLA ``queue_take_front`` slice — which makes
    the zero-capacity degenerate (a cap-0 spill-only channel) an explicit
    early-out here: nothing can be stored, so the pop is the empty (0, w)
    buffer and every offered row is a counted drop, reproducing XLA's
    empty-slice behavior instead of relying on it.
    """
    cap, w = data.shape
    if cap == 0:
        drops = valid.sum(dtype=jnp.int32)
        return (jnp.zeros((0, w), jnp.int32), jnp.zeros((0,), bool),
                data, count + 0, drops)
    data2, count2, drops = queue_append(data, count, rows, valid)
    eff = min(max_n, cap)
    n_pop = jnp.minimum(n, count2)
    taken = data2[:eff]
    tvalid = jnp.arange(eff, dtype=jnp.int32) < n_pop
    src = jnp.minimum(jnp.arange(cap, dtype=jnp.int32) + n_pop, cap - 1)
    return taken, tvalid, data2[src], count2 - n_pop, drops


def queue_append(data: jax.Array, count: jax.Array, rows: jax.Array,
                 valid: jax.Array):
    """Push-only FIFO tail append — the in-kernel twin of
    ``core.queues.queue_push`` (same cumsum slot claim, same trash-slot
    scatter, bit-identical), used by the fused leg to absorb the mid-round
    spill re-queue and the split-remainder re-push that previously ran as
    XLA glue between kernels.  Returns (new_data, new_count, drops)."""
    cap, w = data.shape
    mi = valid.astype(jnp.int32)
    offs = count + jnp.cumsum(mi) - mi
    ok = valid & (offs < cap)
    slot = jnp.where(ok, offs, jnp.int32(cap))  # cap = trash slot
    ext = jnp.concatenate([data, jnp.zeros((1, w), jnp.int32)], axis=0)
    data2 = ext.at[slot].set(rows)[:cap]
    n_push = ok.sum(dtype=jnp.int32)
    return data2, count + n_push, mi.sum() - n_push


def segment_gather(edge_dst: jax.Array, edge_val: jax.Array,
                   start: jax.Array, stop: jax.Array, rv: jax.Array,
                   max_t2: int):
    """Body of :func:`edge_scan_gather`: the T2 ragged segment gather out
    of the tile's edge shard.  Returns (nb, w, jvalid), each (R, max_t2)."""
    e_chunk = edge_dst.shape[0]
    length = jnp.where(rv, stop - start, 0)
    local0 = jnp.where(rv, start % e_chunk, 0)
    j = jnp.arange(max_t2, dtype=jnp.int32)[None, :]
    eidx = local0[:, None] + j                    # (R, MAX_T2)
    jvalid = rv[:, None] & (j < length[:, None])
    eidx_c = jnp.minimum(eidx, e_chunk - 1)
    nb = edge_dst[eidx_c]
    return nb, edge_val[eidx_c], jvalid & (nb >= 0)


def segment_stream(edge_dst: jax.Array, edge_val: jax.Array,
                   start: jax.Array, stop: jax.Array, rv: jax.Array,
                   max_t2: int, window: int):
    """Body of :func:`edge_scan_stream`: T2 over an **HBM-resident** edge
    shard, consumed through double-buffered segment DMA windows.

    The prefetched head flit of each delivered range message carries the
    global edge index — the true scalar-prefetch form of T2: the local
    offset ``start % e_chunk`` is known *before* the edge data is touched,
    so the engine issues the window fetch for message ``r`` while message
    ``r-1`` computes.  This body is the value-exact emulation of that
    discipline: each message stages the two consecutive ``window``-sized
    DMA windows covering its segment (``base = (local0 // window) *
    window``; the aligned window plus its successor — the double buffer),
    then gathers its ``<= max_t2`` edges out of the staging buffer only.

    Bit-identity with the VMEM-direct :func:`segment_gather`: upstream
    ``range_split`` bounds every message at the chunk border and at
    MAX_T2, and :func:`repro.mem.resolve_window` guarantees ``window >=
    max_t2`` — so a segment starting anywhere in the aligned window ends
    strictly inside the next one, and every *valid* lane reads the same
    shard element either way (invalid lanes are don't-cares masked by
    ``jvalid`` at every consumer).  The engine counts 2 windows per
    delivered message into ``Stats.hbm_windows`` and prices the streamed
    words at ``t_hbm``/``e_hbm`` (DESIGN.md "Memory spaces").

    Returns (nb, w, jvalid), each (R, max_t2) — same contract as
    :func:`segment_gather`.
    """
    e_chunk = edge_dst.shape[0]
    length = jnp.where(rv, stop - start, 0)
    local0 = jnp.where(rv, start % e_chunk, 0)
    base = (local0 // window) * window        # aligned window start
    # Stage the double buffer: 2*window consecutive elements from base.
    k = jnp.arange(2 * window, dtype=jnp.int32)[None, :]
    sidx = jnp.minimum(base[:, None] + k, e_chunk - 1)  # (R, 2*window)
    stage_dst = edge_dst[sidx]
    stage_val = edge_val[sidx]
    # Gather the segment out of the staging buffer only.
    j = jnp.arange(max_t2, dtype=jnp.int32)[None, :]
    jvalid = rv[:, None] & (j < length[:, None])
    off = jnp.minimum((local0 - base)[:, None] + j, 2 * window - 1)
    nb = jnp.take_along_axis(stage_dst, off, axis=1)
    w = jnp.take_along_axis(stage_val, off, axis=1)
    return nb, w, jvalid & (nb >= 0)


def scatter_body(target: jax.Array, lidx: jax.Array, vals: jax.Array,
                 valid: jax.Array, op: str):
    """Body of :func:`fold_scatter`: the T3 owner-local scatter-min /
    scatter-add (``lidx`` maps invalid rows to the ``v_chunk`` trash slot).
    The ``kernels/scatter_update`` in-kernel scatter idiom — atomic-free
    because every write targets the tile's own shard."""
    v_chunk = target.shape[0]
    neutral = _INF if op == "min" else 0.0
    ext = jnp.concatenate(
        [target, jnp.full((1,), neutral, jnp.float32)])
    masked = jnp.where(valid, vals, jnp.float32(neutral))
    if op == "min":
        ext = ext.at[lidx].min(masked)
    else:
        ext = ext.at[lidx].add(masked)
    return ext[:v_chunk]


# ==========================================================================
# The fused leg: one pallas_call per engine channel leg.
# ==========================================================================

def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _lane_pad(shape: tuple) -> tuple:
    """The (8, 128) f32 tile rule: last dim to a lane multiple, second-last
    (when present) to a sublane multiple.  Scalars ride as (1,) unpadded
    (they belong in SMEM on real hardware, not a lane tile)."""
    s = list(shape)
    s[-1] = _ceil_to(s[-1], 128)
    if len(s) >= 2:
        s[-2] = _ceil_to(s[-2], 8)
    return tuple(s)


def fused_leg_call(fn, *operands, interpret: bool = True,
                   pad_lanes: bool = False):
    """Run the per-tile stage ``fn(*operands)`` as ONE Pallas launch.

    ``fn`` is a pure pytree -> pytree function (an engine channel leg:
    state in, state + messages out).  The harness flattens the operand
    pytrees into kernel refs, makes the *stage itself* the kernel body —
    so every intermediate of the chained frontier-pop -> FIFO turn ->
    spill re-queue -> remainder re-push -> segment-gather -> scatter-fold
    stays resident in the launch (VMEM on a TPU) — and unflattens the
    outputs, shaped via ``jax.eval_shape``.  Leaf plumbing:

    * () scalars ride as (1,) refs and are restored inside the body;
    * zero-size leaves (e.g. a cap-0 queue's data) bypass the launch —
      materialized as zeros on each side, since a 0-element ref is
      meaningless;
    * ``pad_lanes=True`` pads every non-scalar block to the (8, 128)
      sublane x lane f32 tile on the way in (zeros) and slices each ref
      back to its logical shape inside the body, so TPU-aligned blocks
      and the interpret path compute the identical values.

    Under ``LocalComm`` the engine vmaps this call and the batching rule
    turns the tile axis into the Pallas grid (one grid program per tile);
    a serving lane axis batches the same way.  Counts as one launch with
    :mod:`repro.kernels.engine.launches`.
    """
    flat_in, in_tree = jax.tree.flatten(operands)
    flat_in = [jnp.asarray(x) for x in flat_in]
    out_avals = jax.eval_shape(fn, *operands)
    flat_out, out_tree = jax.tree.flatten(out_avals)
    in_specs = [(tuple(x.shape), x.dtype) for x in flat_in]
    out_specs = [(tuple(a.shape), a.dtype) for a in flat_out]

    def live(shape):
        return int(np.prod(shape, dtype=np.int64)) > 0 or shape == ()

    def to_call(x):
        shape = tuple(x.shape)
        if shape == ():
            return x.reshape(1)
        tgt = _lane_pad(shape) if pad_lanes else shape
        if tgt != shape:
            x = jnp.pad(x, [(0, t - s) for s, t in zip(shape, tgt)])
        return x

    def from_ref(ref, shape):
        v = ref[...]
        if shape == ():
            return v[0]
        return v[tuple(slice(0, s) for s in shape)]

    n_in = sum(live(s) for s, _ in in_specs)

    def kernel(*refs):
        it = iter(refs[:n_in])
        vals = []
        for shape, dtype in in_specs:
            if not live(shape):
                vals.append(jnp.zeros(shape, dtype))
            else:
                vals.append(from_ref(next(it), shape))
        outs = jax.tree.leaves(fn(*jax.tree.unflatten(in_tree, vals)))
        ot = iter(refs[n_in:])
        for o, (shape, _) in zip(outs, out_specs):
            if not live(shape):
                continue
            ref = next(ot)
            if shape == ():
                ref[...] = o.reshape(1)
            else:
                tgt = _lane_pad(shape) if pad_lanes else shape
                if tgt != shape:
                    o = jnp.pad(o, [(0, t - s) for s, t in zip(shape, tgt)])
                ref[...] = o

    call_ins = [to_call(x) for x, (s, _) in zip(flat_in, in_specs)
                if live(s)]
    out_shape = tuple(
        jax.ShapeDtypeStruct(
            (1,) if s == () else (_lane_pad(s) if pad_lanes else s), d)
        for s, d in out_specs if live(s))
    record()
    raw = pl.pallas_call(kernel, out_shape=out_shape,
                         interpret=interpret)(*call_ins)
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    it = iter(raw)
    restored = []
    for shape, dtype in out_specs:
        if not live(shape):
            restored.append(jnp.zeros(shape, dtype))
        elif shape == ():
            restored.append(next(it)[0])
        else:
            restored.append(
                next(it)[tuple(slice(0, s) for s in shape)])
    return jax.tree.unflatten(out_tree, restored)


# ==========================================================================
# Standalone kernels (PR4's four-launch leg; the pallas_fuse=False path and
# the kernel-twin test surface).  Each wraps one pure body in a pallas_call;
# the plain public wrappers record the launch, then dispatch to an inner
# jitted impl (a jit cache hit would skip a record placed inside).
# ==========================================================================

# --------------------------------------------------------------------------
# T4: fused frontier pop (take_first_k as one kernel).
# --------------------------------------------------------------------------

def _frontier_pop_kernel(k_ref, mask_ref, idx_ref, valid_ref, rem_ref):
    idx, valid, rem = frontier_take(mask_ref[...], k_ref[0],
                                    idx_ref.shape[0])
    idx_ref[...] = idx
    valid_ref[...] = valid
    rem_ref[...] = rem


@functools.partial(jax.jit, static_argnames=("k_max", "interpret"))
def _frontier_pop(mask, k, k_max, interpret):
    n = mask.shape[0]
    return pl.pallas_call(
        _frontier_pop_kernel,
        out_shape=(jax.ShapeDtypeStruct((k_max,), jnp.int32),
                   jax.ShapeDtypeStruct((k_max,), jnp.bool_),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)),
        interpret=interpret,
    )(jnp.asarray(k, jnp.int32).reshape(1), mask)


def frontier_pop(mask: jax.Array, k: jax.Array, k_max: int,
                 interpret: bool = True):
    """Pop the first ``min(k, popcount)`` set bits of the tile's frontier
    bitmap, FIFO by position — the Pallas twin of
    :func:`repro.core.program.take_first_k`.

    mask: (n,) bool; k: () int32 dynamic budget (<= k_max).
    Returns (idx (k_max,) i32, valid (k_max,) bool, cleared_mask (n,) bool).
    Invalid slots of ``idx`` hold 0 (the XLA twin holds unpopped positions
    there); both are don't-cares masked by ``valid`` everywhere downstream.
    """
    record()
    return _frontier_pop(mask, k, k_max, interpret)


# --------------------------------------------------------------------------
# Fused circular-FIFO turn: push fresh tasks, pop the TSU budget.
# --------------------------------------------------------------------------

def _queue_push_pop_kernel(n_ref, data_ref, count_ref, rows_ref, pvalid_ref,
                           taken_ref, tvalid_ref, ndata_ref, ncount_ref,
                           drops_ref):
    taken, tvalid, ndata, ncount, drops = fifo_turn(
        data_ref[...], count_ref[0], rows_ref[...], pvalid_ref[...],
        n_ref[0], taken_ref.shape[0])
    taken_ref[...] = taken
    tvalid_ref[...] = tvalid
    ndata_ref[...] = ndata
    ncount_ref[0] = ncount
    drops_ref[0] = drops


@functools.partial(jax.jit, static_argnames=("max_n", "interpret"))
def _queue_push_pop(data, count, rows, valid, n, max_n, interpret):
    return pl.pallas_call(
        _queue_push_pop_kernel,
        out_shape=(jax.ShapeDtypeStruct((max_n, data.shape[1]), jnp.int32),
                   jax.ShapeDtypeStruct((max_n,), jnp.bool_),
                   jax.ShapeDtypeStruct(data.shape, jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(jnp.asarray(n, jnp.int32).reshape(1), data,
      jnp.asarray(count, jnp.int32).reshape(1), rows, valid)


def queue_push_pop(data: jax.Array, count: jax.Array, rows: jax.Array,
                   valid: jax.Array, n: jax.Array, max_n: int,
                   interpret: bool = True):
    """One fused FIFO turn: ``queue_push(rows[valid])`` then
    ``queue_take_front(min(n, count'))`` in a single kernel.

    data: (cap, w) i32 queue buffer whose first ``count`` rows are live;
    rows/valid: the fresh tasks; n: () i32 dynamic pop budget (<= max_n).
    Returns (taken (max_n, w), taken_valid (max_n,), new_data (cap, w),
    new_count () i32, drops () i32).  Live rows (< new_count) and the taken
    buffer are bit-identical to the two-call XLA path; rows at or beyond
    the live count are unobservable garbage in both backends.

    The zero-capacity degenerate (a cap-0 spill-only channel) is an
    explicit early-out — no kernel is launched, the pop is the empty
    ``(0, w)`` buffer, every offered row counts as a drop — matching the
    shapes XLA's empty ``queue_take_front`` slice produces instead of
    relying on them.
    """
    cap = data.shape[0]
    if cap == 0:
        return fifo_turn(data, count, rows, valid, n, max_n)
    assert max_n <= cap, f"pop budget bound {max_n} > queue capacity {cap}"
    record()
    taken, tvalid, ndata, ncount, drops = _queue_push_pop(
        data, count, rows, valid, n, max_n, interpret)
    return taken, tvalid, ndata, ncount[0], drops[0]


# --------------------------------------------------------------------------
# T2: segment gather over the tile's edge shard.
# --------------------------------------------------------------------------

def _edge_scan_kernel(edge_dst_ref, edge_val_ref, start_ref, stop_ref,
                      rv_ref, nb_ref, w_ref, jvalid_ref):
    nb, w, jvalid = segment_gather(
        edge_dst_ref[...], edge_val_ref[...], start_ref[...], stop_ref[...],
        rv_ref[...], nb_ref.shape[1])
    nb_ref[...] = nb
    w_ref[...] = w
    jvalid_ref[...] = jvalid


@functools.partial(jax.jit, static_argnames=("max_t2", "interpret"))
def _edge_scan_gather(edge_dst, edge_val, start, stop, rv, max_t2,
                      interpret):
    r = start.shape[0]
    return pl.pallas_call(
        _edge_scan_kernel,
        out_shape=(jax.ShapeDtypeStruct((r, max_t2), jnp.int32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.float32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.bool_)),
        interpret=interpret,
    )(edge_dst, edge_val, start, stop, rv)


def edge_scan_gather(edge_dst: jax.Array, edge_val: jax.Array,
                     start: jax.Array, stop: jax.Array, rv: jax.Array,
                     max_t2: int, interpret: bool = True):
    """The T2 segment gather: for each delivered range message, read its
    ``[start, stop)`` slice (bounded by MAX_T2 upstream) out of the tile's
    VMEM-resident edge shard.

    The head flit is the address — the received global edge index maps to a
    local offset (``start % e_chunk``) and indexes straight into the shard,
    the ragged-CSR analogue of ``kernels/spmv``'s scalar-prefetched column
    index.  Returns (nb (R, max_t2) i32, w (R, max_t2) f32,
    jvalid (R, max_t2) bool), bit-identical to the inline XLA gather in
    :func:`repro.core.program.edge_scan`.
    """
    record()
    return _edge_scan_gather(edge_dst, edge_val, start, stop, rv, max_t2,
                             interpret)


# --------------------------------------------------------------------------
# T2 over an HBM-resident shard: double-buffered segment-DMA stream.
# --------------------------------------------------------------------------

def _edge_stream_kernel(edge_dst_ref, edge_val_ref, start_ref, stop_ref,
                        rv_ref, nb_ref, w_ref, jvalid_ref, *, window):
    nb, w, jvalid = segment_stream(
        edge_dst_ref[...], edge_val_ref[...], start_ref[...], stop_ref[...],
        rv_ref[...], nb_ref.shape[1], window)
    nb_ref[...] = nb
    w_ref[...] = w
    jvalid_ref[...] = jvalid


@functools.partial(jax.jit, static_argnames=("max_t2", "window", "interpret"))
def _edge_scan_stream(edge_dst, edge_val, start, stop, rv, max_t2, window,
                      interpret):
    r = start.shape[0]
    return pl.pallas_call(
        functools.partial(_edge_stream_kernel, window=window),
        out_shape=(jax.ShapeDtypeStruct((r, max_t2), jnp.int32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.float32),
                   jax.ShapeDtypeStruct((r, max_t2), jnp.bool_)),
        interpret=interpret,
    )(edge_dst, edge_val, start, stop, rv)


def edge_scan_stream(edge_dst: jax.Array, edge_val: jax.Array,
                     start: jax.Array, stop: jax.Array, rv: jax.Array,
                     max_t2: int, window: int, interpret: bool = True):
    """The T2 segment scan when the tile's edge shard is declared in HBM:
    each delivered range message stages its two covering DMA windows into
    VMEM (the double buffer) and gathers its segment from the staging
    buffer — never word-random from the shard (:func:`segment_stream` is
    the body; the fused leg calls it directly via ``Ctx.fused``).

    Bit-identical in every valid lane to :func:`edge_scan_gather` on the
    same shard (the space-equivalence contract,
    ``tests/test_memspace.py``); requires ``window >= max_t2``
    (:func:`repro.mem.resolve_window`).
    """
    record()
    return _edge_scan_stream(edge_dst, edge_val, start, stop, rv, max_t2,
                             window, interpret)


# --------------------------------------------------------------------------
# T3: CQ drain + owner-local scatter fold.
# --------------------------------------------------------------------------

def _fold_scatter_kernel(target_ref, lidx_ref, vals_ref, valid_ref, out_ref,
                         *, op):
    out_ref[...] = scatter_body(target_ref[...], lidx_ref[...],
                                vals_ref[...], valid_ref[...], op)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def _fold_scatter(target, lidx, vals, valid, op, interpret):
    return pl.pallas_call(
        functools.partial(_fold_scatter_kernel, op=op),
        out_shape=jax.ShapeDtypeStruct(target.shape, jnp.float32),
        interpret=interpret,
    )(target, lidx, vals, valid)


def fold_scatter(target: jax.Array, lidx: jax.Array, vals: jax.Array,
                 valid: jax.Array, op: str = "min", interpret: bool = True):
    """The T3 fold: drain a delivered CQ buffer into the tile's owned
    ``(v_chunk,)`` slice — scatter-min for relaxations, scatter-add for
    accumulations.  Atomic-free: all writes land in this tile's own shard
    (Section III-A), so the kernel needs no synchronization.

    target: (v_chunk,) f32; lidx: (R,) i32 local indices with ``v_chunk``
    as the trash slot for invalid rows; vals/valid: the drained payloads.
    Bit-identical to the XLA ``ext.at[lidx].min/add`` twin in
    :func:`repro.core.program.scatter_fold`.
    """
    assert op in ("min", "add"), op
    record()
    return _fold_scatter(target, lidx, vals, valid, op, interpret)
