"""Trace-time Pallas launch accounting.

A "launch" is one ``pl.pallas_call`` dispatch.  On real hardware each one
costs a fixed kernel-launch / sync overhead on top of the tile work
(``benchmarks/kern_micro.py`` measures it), so the engine wants to *count*
them: ``Stats.launches`` reports how many kernel dispatches one round
issues, and fig11 reports the fused-vs-unfused delta.

The count is taken at **trace time**: the engine round is traced exactly
once per compile (the whole traversal is one ``lax.while_loop``), so the
number of ``pallas_call`` sites traced into the round body *is* the number
of launches the hardware would issue per round — a Python integer, exact,
and identical across LocalComm/vmap and shard_map executions of the same
round.  Every public kernel wrapper in :mod:`repro.kernels.engine.kernel`
calls :func:`record` from its (non-jitted) entry point; the engine brackets
its round trace with :func:`tally`.

Counts nest: a tally sees every launch recorded while it is the innermost
open tally.  When no tally is open, :func:`record` is a no-op — standalone
kernel calls (tests, microbenches) cost nothing.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


class Tally:
    """Mutable launch counter; ``.n`` is valid once its context exits."""

    def __init__(self):
        self.n = 0


def record(n: int = 1) -> None:
    """Note ``n`` kernel launches against the innermost open tally."""
    for t in _stack():
        t.n += n


@contextlib.contextmanager
def tally():
    """Open a launch-count scope: ``with tally() as t: ...; t.n``."""
    t = Tally()
    _stack().append(t)
    try:
        yield t
    finally:
        _stack().pop()
