"""Pallas execution backend for the Dalorex engine round (one grid program
= one tile; see kernel.py and DESIGN.md "Pallas backend").

Standalone kernels (``frontier_pop``/``queue_push_pop``/``edge_scan_gather``
/``edge_scan_stream``/``fold_scatter``), their pure value->value bodies
(``frontier_take``/``fifo_turn``/``queue_append``/``segment_gather``/
``segment_stream``/``scatter_body``), the single-launch fused-leg harness
(``fused_leg_call``), and trace-time launch accounting
(``launches.tally``/``launches.record``).  ``segment_stream`` /
``edge_scan_stream`` are the HBM-resident-shard form of T2: double-buffered
segment DMA windows, bit-identical in valid lanes to the VMEM-direct
gather (DESIGN.md "Memory spaces")."""
from repro.kernels.engine.kernel import (edge_scan_gather, edge_scan_stream,
                                         fifo_turn, fold_scatter,
                                         frontier_pop, frontier_take,
                                         fused_leg_call, queue_append,
                                         queue_push_pop, scatter_body,
                                         segment_gather, segment_stream)
from repro.kernels.engine.launches import record, tally

__all__ = ["edge_scan_gather", "edge_scan_stream", "fold_scatter",
           "frontier_pop", "queue_push_pop", "frontier_take", "fifo_turn",
           "queue_append", "segment_gather", "segment_stream",
           "scatter_body", "fused_leg_call", "record", "tally"]
