"""Pallas execution backend for the Dalorex engine round (one grid program
= one tile; see kernel.py and DESIGN.md "Pallas backend")."""
from repro.kernels.engine.kernel import (edge_scan_gather, fold_scatter,
                                         frontier_pop, queue_push_pop)

__all__ = ["edge_scan_gather", "fold_scatter", "frontier_pop",
           "queue_push_pop"]
