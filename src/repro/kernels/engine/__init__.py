"""Pallas execution backend for the Dalorex engine round (one grid program
= one tile; see kernel.py and DESIGN.md "Pallas backend").

Standalone kernels (``frontier_pop``/``queue_push_pop``/``edge_scan_gather``
/``fold_scatter``), their pure value->value bodies (``frontier_take``/
``fifo_turn``/``queue_append``/``segment_gather``/``scatter_body``), the
single-launch fused-leg harness (``fused_leg_call``), and trace-time launch
accounting (``launches.tally``/``launches.record``)."""
from repro.kernels.engine.kernel import (edge_scan_gather, fifo_turn,
                                         fold_scatter, frontier_pop,
                                         frontier_take, fused_leg_call,
                                         queue_append, queue_push_pop,
                                         scatter_body, segment_gather)
from repro.kernels.engine.launches import record, tally

__all__ = ["edge_scan_gather", "fold_scatter", "frontier_pop",
           "queue_push_pop", "frontier_take", "fifo_turn", "queue_append",
           "segment_gather", "scatter_body", "fused_leg_call", "record",
           "tally"]
