"""Pallas TPU block-ELL SpMV — the paper's SPMV workload adapted to the MXU.

Dalorex on a TPU core grid: each (row-block) is a tile's *owned* data — all
accumulation into y happens at its owner (atomic-free, Section III-A); the
gather of x column-blocks is the arriving task message.  The column index
drives the x BlockSpec through **scalar prefetch** (the TPU-native form of
the paper's headerless index-routing: the index IS the route, here it IS the
DMA descriptor).

Grid (row_blocks, slots); x blocks stream by bcols[i, s]; empty slots point
at a zero pad block.  Block size 128 aligns the MXU; VMEM per step =
(128x128 + 2x128) fp32 ~ 66 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(bcols_ref, bvals_ref, x_ref, y_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    i = pl.program_id(0)
    col = bcols_ref[i, s]

    @pl.when(col >= 0)
    def _acc():
        blk = bvals_ref[0, 0].astype(jnp.float32)   # (b, b)
        xb = x_ref[0].astype(jnp.float32)           # (b,)
        y_ref[...] += (blk @ xb[:, None])[:, 0].reshape(y_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_block_ell(bvals, bcols, x_pad, interpret: bool = True):
    """bvals: (NB, S, b, b); bcols: (NB, S) i32 (-1 empty);
    x_pad: (NB*b,).  Returns y (NB*b,) f32."""
    nb, slots, b, _ = bvals.shape
    # -1 -> the zero pad block appended at index nb (never read: masked by
    # pl.when, but the index map must stay in range)
    x_blocks = jnp.concatenate(
        [x_pad.reshape(nb, b), jnp.zeros((1, b), x_pad.dtype)], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, slots),
        in_specs=[
            pl.BlockSpec((1, 1, b, b), lambda i, s, cols: (i, s, 0, 0)),
            pl.BlockSpec(
                (1, b),
                lambda i, s, cols: (jnp.where(cols[i, s] >= 0,
                                              cols[i, s], nb), 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i, s, cols: (i, 0)),
    )
    y = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, b), jnp.float32),
        interpret=interpret,
    )(bcols, bvals, x_blocks)
    return y.reshape(nb * b)
