"""Oracles + host-side format conversion for block-ELL SpMV."""
from __future__ import annotations

import numpy as np


def to_block_ell(n: int, rows, cols, vals, block: int, slots: int | None
                 = None):
    """COO -> block-ELL.  Returns (bvals (NB,S,b,b) f32, bcols (NB,S) i32,
    n_pad).  bcols -1 marks an empty slot.  Raises if a row-block needs more
    than ``slots`` column-blocks (caller picks slots from the histogram)."""
    nb = (n + block - 1) // block
    n_pad = nb * block
    buckets: dict[tuple[int, int], np.ndarray] = {}
    for r, c, v in zip(rows, cols, vals):
        key = (int(r) // block, int(c) // block)
        blk = buckets.get(key)
        if blk is None:
            blk = buckets[key] = np.zeros((block, block), np.float32)
        blk[int(r) % block, int(c) % block] += v
    per_row: dict[int, list] = {}
    for (br, bc), blk in sorted(buckets.items()):
        per_row.setdefault(br, []).append((bc, blk))
    width = max((len(v) for v in per_row.values()), default=1)
    if slots is None:
        slots = width
    assert width <= slots, f"row-block needs {width} slots > {slots}"
    bvals = np.zeros((nb, slots, block, block), np.float32)
    bcols = np.full((nb, slots), -1, np.int32)
    for br, lst in per_row.items():
        for s, (bc, blk) in enumerate(lst):
            bvals[br, s] = blk
            bcols[br, s] = bc
    return bvals, bcols, n_pad


def spmv_dense_ref(n: int, rows, cols, vals, x):
    """y = A x oracle."""
    y = np.zeros(n, np.float64)
    np.add.at(y, np.asarray(rows),
              np.asarray(vals, np.float64) * np.asarray(x)[np.asarray(cols)])
    return y


def block_ell_ref(bvals, bcols, x_pad):
    """Pure-numpy block-ELL SpMV (the kernel's direct oracle)."""
    nb, slots, b, _ = bvals.shape
    y = np.zeros(nb * b, np.float64)
    for i in range(nb):
        for s in range(slots):
            c = bcols[i, s]
            if c >= 0:
                y[i * b:(i + 1) * b] += bvals[i, s].astype(np.float64) @ \
                    x_pad[c * b:(c + 1) * b]
    return y
