"""jit'd entry point for the WKV6 recurrence: picks the Pallas TPU kernel or
the chunked jnp reference (bit-compatible algorithm, same chunking)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rwkv6 import ref


def wkv6(r, k, v, w_log, u, state0=None, use_pallas: bool = False,
         chunk: int = 16):
    """r,k,v,w_log: (B,S,H,K); u: (H,K).  Returns (y, final_state)."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w_log = w_log.astype(jnp.float32)
    if r.shape[1] == 1 and state0 is not None:  # decode fast path
        sq = lambda a: a[:, 0]
        state, y = ref.wkv6_step(state0, sq(r), sq(k), sq(v),
                                 jnp.exp(sq(w_log)), u)
        return y[:, None], state
    if use_pallas:
        from repro.kernels.rwkv6.kernel import wkv6_pallas
        return wkv6_pallas(r, k, v, w_log, u, state0=state0, chunk=chunk)
    return ref.wkv6_chunked(r, k, v, w_log, u, state0=state0, chunk=chunk)
