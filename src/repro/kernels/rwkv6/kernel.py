"""Pallas TPU kernel for the chunked WKV6 recurrence.

Grid (B, H): each cell owns one head's full sequence in VMEM
(S x K fp32 x 4 tensors; S=4096, K=64 -> 4 MB) and walks it chunk by chunk
with a fori_loop, carrying the (K, K) state in VMEM scratch — the same
chunked algorithm as ref.wkv6_chunked, so the two agree to float tolerance.

This is data-local by construction: the recurrence state never leaves the
core's VMEM; only the (B,S,H,K) activations stream in/out of HBM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 state_scr, *, chunk, n_chunks):
    state_scr[...] = s0_ref[0, 0]
    u = u_ref[0].astype(jnp.float32)                 # (K,)

    def body(c, _):
        sl = pl.ds(c * chunk, chunk)
        rb = r_ref[0, sl, 0, :].astype(jnp.float32)  # (C, K)
        kb = k_ref[0, sl, 0, :].astype(jnp.float32)
        vb = v_ref[0, sl, 0, :].astype(jnp.float32)
        wb = w_ref[0, sl, 0, :].astype(jnp.float32)  # log decay
        L = jnp.cumsum(wb, axis=0)
        pex = L - wb
        r_in = rb * jnp.exp(pex)
        state = state_scr[...]
        y_inter = jax.lax.dot(r_in, state,
                              preferred_element_type=jnp.float32)
        att = jax.lax.dot_general(
            r_in, kb * jnp.exp(-L), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (C, C)
        tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
               > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
        att = jnp.where(tri, att, 0.0)
        y_intra = jax.lax.dot(att, vb, preferred_element_type=jnp.float32)
        y_diag = ((rb * u[None] * kb).sum(-1, keepdims=True)) * vb
        y_ref[0, sl, 0, :] = (y_inter + y_intra + y_diag).astype(y_ref.dtype)
        decay_all = jnp.exp(L[-1])                   # (K,)
        k_dec = kb * jnp.exp(L[-1][None] - L)
        state_scr[...] = decay_all[:, None] * state + jax.lax.dot_general(
            k_dec, vb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)
    sT_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w_log, u, state0=None, chunk: int = 16,
                interpret: bool = True):
    """r,k,v,w_log: (B,S,H,K) fp32; u: (H,K).  Returns (y, final state)."""
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    if state0 is None:
        state0 = jnp.zeros((B, H, K, K), jnp.float32)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk,
                               n_chunks=S // chunk)
    seq_spec = pl.BlockSpec((1, S, 1, K), lambda b, h: (b, 0, h, 0))
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, K), lambda b, h: (h, 0)),
                  pl.BlockSpec((1, 1, K, K), lambda b, h: (b, h, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, 1, K, K), lambda b, h: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, K), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, K, K), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u, state0)
    return y, sT
