"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence, chunked form.

Per head with key/value dim K, data-dependent per-channel decay w_t in (0,1):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state K x K)
    y_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t    (u = per-channel bonus)

The chunked closed form (the algorithm the Pallas kernel implements):
within a chunk of C steps, with L_t = inclusive cumsum of log w and
Pex_t = L_t - log w_t (exclusive),

    y_t = (r_t * exp(Pex_t)) S_prev
        + sum_{s<t} (r_t . (k_s * exp(Pex_t - L_s))) v_s
        + (r_t . (u * k_t)) v_t
    S'  = diag(exp(L_{C-1})) S_prev + sum_s diag(exp(L_{C-1} - L_s)) k_s^T v_s
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_step(state, r, k, v, w, u):
    """One decode step.  state: (B,H,K,K); r,k,v,w: (B,H,K); u: (H,K).
    Returns (new_state, y (B,H,K))."""
    y = jnp.einsum("bhk,bhkv->bhv", r, state) \
        + jnp.einsum("bhk,bhk,bhv->bhv", r, u[None] * k, v)
    new_state = w[..., None] * state + k[..., None] * v[..., None, :]
    return new_state, y


def wkv6_chunked(r, k, v, w_log, u, state0=None, chunk: int = 64):
    """r,k,v: (B,S,H,K) fp32; w_log: (B,S,H,K) = log decay (<= 0);
    u: (H,K).  Returns (y (B,S,H,K), final state (B,H,K,K))."""
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    if state0 is None:
        state0 = jnp.zeros((B, H, K, K), jnp.float32)

    rc = r.reshape(B, n, chunk, H, K).swapaxes(0, 1)
    kc = k.reshape(B, n, chunk, H, K).swapaxes(0, 1)
    vc = v.reshape(B, n, chunk, H, K).swapaxes(0, 1)
    wc = w_log.reshape(B, n, chunk, H, K).swapaxes(0, 1)

    def body(state, xs):
        rb, kb, vb, wb = xs  # (B, C, H, K)
        L = jnp.cumsum(wb, axis=1)              # inclusive
        pex = L - wb                            # exclusive
        r_in = rb * jnp.exp(pex)
        # inter-chunk: y += (r * exp(Pex)) @ S_prev
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_in, state)
        # intra-chunk strictly-lower-triangular attention
        att = jnp.einsum("bthk,bshk->bhts", r_in, kb * jnp.exp(-L))
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhts,bshv->bthv", att, vb)
        # diagonal bonus term
        y_diag = jnp.einsum("bchk,bchk,bchv->bchv", rb, u[None, None] * kb,
                            vb)
        y = y_inter + y_intra + y_diag
        # state update
        decay_all = jnp.exp(L[:, -1])           # (B, H, K)
        k_dec = kb * jnp.exp(L[:, -1][:, None] - L)
        s_new = decay_all[..., None] * state + jnp.einsum(
            "bchk,bchv->bhkv", k_dec, vb)
        return s_new, y

    state, ys = jax.lax.scan(body, state0, (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, K)
    return y, state


def wkv6_scan_oracle(r, k, v, w_log, u, state0=None):
    """Step-by-step scan — the ground truth the chunked form must match."""
    B, S, H, K = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, K, K), jnp.float32)
    w = jnp.exp(w_log)

    def body(state, xs):
        rt, kt, vt, wt = xs
        state, y = wkv6_step(state, rt, kt, vt, wt, u)
        return state, y

    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    state, ys = jax.lax.scan(body, state0, xs)
    return ys.swapaxes(0, 1), state
