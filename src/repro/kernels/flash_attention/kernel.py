"""Pallas TPU flash attention (forward), grouped-query aware.

Grid (B, H, nq, nk) with VMEM scratch carrying the online-softmax state
(m, l, acc) across the kv dimension — the canonical MXU-tiled flash
structure.  GQA is handled in the index map (query head h reads kv head
h // G), so kv is never materialized repeated.  Causal and sliding-window
masks are computed from block-local iotas.

Block shapes default to (128, 128): MXU-aligned, and the working set
  q (bq, hd) + k/v (bk, hd) + p (bq, bk) + acc (bq, hd)
stays well inside the ~16 MB VMEM for hd <= 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale, block_q, block_k, window, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(2)
    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos <= qpos  # causal
    if window:
        mask &= kpos > qpos - window

    # skip fully-masked tiles (above the diagonal / outside the window)
    @pl.when(jnp.any(mask))
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd).  Causal; optional window.
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    sm_scale = hd ** -0.5

    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, window=window, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
