"""jit'd entry point: Pallas flash kernel (TPU target; interpret=True on
CPU) or the jnp oracle."""
from __future__ import annotations

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, window: int = 0, use_pallas: bool = False,
              interpret: bool = True, **kw):
    if use_pallas:
        return flash_attention(q, k, v, window=window, interpret=interpret,
                               **kw)
    return attention_ref(q, k, v, window=window)
