"""Pure-jnp oracle: naive causal (windowed) attention with GQA."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, window: int = 0):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * hd ** -0.5
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)
