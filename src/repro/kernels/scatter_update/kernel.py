"""Pallas TPU binned segment scatter — the Dalorex T3 apply step.

The routing engine delivers updates already *binned by owner block* (that is
the whole point of the data-local model), so the kernel never contends:
grid cell i folds its own updates into its own block of the value array —
atomic-free by ownership, exactly Section III-A.

TPU adaptation: scatters are hostile to the VPU, so the fold is expressed
as dense one-hot algebra on an MXU/VPU-friendly (cap, b) tile:

  add:  y += vals @ onehot           (one 128x-aligned matmul)
  min:  y = min(y, min_c where(onehot, vals, +inf))  (masked row reduce)

Duplicate indices within a bin are handled correctly by both forms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 3.4e38  # python float: pallas kernels cannot capture traced consts


def _scatter_kernel(base_ref, idx_ref, vals_ref, out_ref, *, op):
    base = base_ref[0].astype(jnp.float32)          # (b,)
    idx = idx_ref[0]                                # (cap,)
    vals = vals_ref[0].astype(jnp.float32)          # (cap,)
    b = base.shape[0]
    cap = idx.shape[0]
    onehot = (idx[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (cap, b), 1))
    onehot &= (idx >= 0)[:, None]
    if op == "add":
        contrib = jax.lax.dot(vals[None, :].astype(jnp.float32),
                              onehot.astype(jnp.float32),
                              preferred_element_type=jnp.float32)[0]
        out_ref[0] = base + contrib
    else:  # min
        masked = jnp.where(onehot, vals[:, None], INF)
        out_ref[0] = jnp.minimum(base, masked.min(axis=0))


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def scatter_segments(base, idx, vals, op: str = "min",
                     interpret: bool = True):
    """base: (NB, b) f32; idx: (NB, cap) i32 (-1 empty); vals: (NB, cap)."""
    nb, b = base.shape
    cap = idx.shape[1]
    kernel = functools.partial(_scatter_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b), jnp.float32),
        interpret=interpret,
    )(base, idx, vals)
