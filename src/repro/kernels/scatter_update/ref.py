"""Oracle for the binned segment scatter (Dalorex T3)."""
from __future__ import annotations

import numpy as np


def scatter_ref(base, idx, vals, op: str):
    """base: (NB, b); idx: (NB, cap) local indices (-1 empty);
    vals: (NB, cap).  op: "min" | "add".  Returns updated (NB, b)."""
    out = np.array(base, np.float32, copy=True)
    nb, cap = idx.shape
    for i in range(nb):
        for c in range(cap):
            j = idx[i, c]
            if j >= 0:
                if op == "min":
                    out[i, j] = min(out[i, j], vals[i, c])
                else:
                    out[i, j] += vals[i, c]
    return out
