"""Pure-jnp oracle for the Mamba2 SSD recurrence, chunked form.

Per head with head dim P and state dim N, scalar-per-head decay
a_t = exp(-exp(A_log) * dt_t):

    h_t = a_t h_{t-1} + dt_t * x_t B_t^T        (state P x N)
    y_t = h_t C_t

Chunked closed form (L_t = inclusive cumsum of log a within the chunk):

    y_t = C_t (exp(L_t) h_prev)^T
        + sum_{s<=t} exp(L_t - L_s) dt_s (C_t . B_s) x_s
    h'  = exp(L_last) h_prev + sum_s exp(L_last - L_s) dt_s x_s B_s^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_step(state, x, dt, a_log, Bv, Cv):
    """One decode step.  state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    a_log: (H,) (= -exp(A_log) pre-scaled by caller? No: raw A_log);
    Bv, Cv: (B,N).  Returns (new_state, y (B,H,P))."""
    a = jnp.exp(jnp.clip(-jnp.exp(a_log)[None] * dt, -4.0, 0.0))  # (B,H)
    new_state = (a[..., None, None] * state
                 + (dt[..., None] * x)[..., None] * Bv[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cv)
    return new_state, y


def ssd_chunked(x, dt, a_log, Bm, Cm, state0=None, chunk: int = 64):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); Bm, Cm: (B,S,N).
    Returns (y (B,S,H,P), final state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)

    # clip per-step log decay so chunk-local cumulated exponents stay inside
    # the fp32 exp range (matches ssd_step; e^-4/step ~ 0 within 2-3 steps)
    loga = jnp.clip(-jnp.exp(a_log)[None, None] * dt, -4.0, 0.0)  # (B,S,H)
    xc = x.reshape(B, n, chunk, H, P).swapaxes(0, 1)
    dc = dt.reshape(B, n, chunk, H).swapaxes(0, 1)
    lc = loga.reshape(B, n, chunk, H).swapaxes(0, 1)
    bc = Bm.reshape(B, n, chunk, N).swapaxes(0, 1)
    cc = Cm.reshape(B, n, chunk, N).swapaxes(0, 1)

    def body(state, xs):
        xb, db, lb, bb, cb = xs  # (B,C,H,P), (B,C,H), (B,C,H), (B,C,N)
        L = jnp.cumsum(lb, axis=1)  # (B,C,H) inclusive
        # inter-chunk
        y_inter = jnp.einsum("bcn,bhpn,bch->bchp", cb, state, jnp.exp(L))
        # intra-chunk (s <= t)
        cb_dot_bb = jnp.einsum("btn,bsn->bts", cb, bb)  # (B,C,C)
        decay = jnp.exp(L[:, :, None] - L[:, None])     # (B,t,s,H)
        tri = jnp.tril(jnp.ones((xb.shape[1], xb.shape[1]), bool))
        att = jnp.where(tri[None, :, :, None],
                        cb_dot_bb[..., None] * decay, 0.0)  # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bsh,bshp->bthp", att, db, xb)
        y = y_inter + y_intra
        # state update
        dec_all = jnp.exp(L[:, -1])                      # (B,H)
        wgt = jnp.exp(L[:, -1][:, None] - L) * db        # (B,C,H)
        s_new = dec_all[..., None, None] * state + jnp.einsum(
            "bch,bchp,bcn->bhpn", wgt, xb, bb)
        return s_new, y

    state, ys = jax.lax.scan(body, state0, (xc, dc, lc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y, state


def ssd_scan_oracle(x, dt, a_log, Bm, Cm, state0=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)

    def body(state, xs):
        xt, dtt, bt, ct = xs
        state, y = ssd_step(state, xt, dtt, a_log, bt, ct)
        return state, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1),
          Cm.swapaxes(0, 1))
    state, ys = jax.lax.scan(body, state0, xs)
    return ys.swapaxes(0, 1), state
