"""Pallas TPU kernel for the chunked Mamba2 SSD recurrence.

Grid (B, H): one head's sequence resident in VMEM, chunk-stepped fori_loop,
(P, N) state in VMEM scratch — same algorithm as ref.ssd_chunked.
B/C projections are shared across heads (ngroups=1), so their blocks are
indexed by batch only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, al_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref,
                state_scr, *, chunk, n_chunks):
    state_scr[...] = s0_ref[0, 0]
    a_log = al_ref[0]  # scalar for this head

    def body(c, _):
        sl = pl.ds(c * chunk, chunk)
        xb = x_ref[0, sl, 0, :].astype(jnp.float32)   # (C, P)
        db = dt_ref[0, sl, 0].astype(jnp.float32)     # (C,)
        bb = b_ref[0, sl, :].astype(jnp.float32)      # (C, N)
        cb = c_ref[0, sl, :].astype(jnp.float32)      # (C, N)
        lb = jnp.clip(-jnp.exp(a_log) * db, -4.0, 0.0)
        L = jnp.cumsum(lb)
        state = state_scr[...]                        # (P, N)
        y_inter = jax.lax.dot(cb * jnp.exp(L)[:, None], state.T,
                              preferred_element_type=jnp.float32)  # (C, P)
        cb_dot_bb = jax.lax.dot_general(
            cb, bb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (C, C) [t, s]
        decay = jnp.exp(L[:, None] - L[None, :])
        tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
               >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
        att = jnp.where(tri, cb_dot_bb * decay, 0.0)
        y_intra = jax.lax.dot(att * db[None, :], xb,
                              preferred_element_type=jnp.float32)
        y_ref[0, sl, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)
        wgt = jnp.exp(L[-1] - L) * db                 # (C,)
        state_scr[...] = jnp.exp(L[-1]) * state + jax.lax.dot_general(
            xb * wgt[:, None], bb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)
    sT_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, a_log, Bm, Cm, state0=None, chunk: int = 16,
               interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); Bm, Cm: (B,S,N)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), jnp.float32)
    kernel = functools.partial(_ssd_kernel, chunk=chunk,
                               n_chunks=S // chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, S, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1,), lambda b, h: (h,)),
            pl.BlockSpec((1, S, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32), a_log,
      Bm.astype(jnp.float32), Cm.astype(jnp.float32), state0)
    return y, sT
