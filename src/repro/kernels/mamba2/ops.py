"""jit'd entry point for the Mamba2 SSD recurrence: Pallas TPU kernel or the
chunked jnp reference (same chunked algorithm)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mamba2 import ref


def ssd(x, dt, a_log, Bm, Cm, state0=None, use_pallas: bool = False,
        chunk: int = 16):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); Bm,Cm: (B,S,N)."""
    if x.shape[1] == 1 and state0 is not None:  # decode fast path
        state, y = ref.ssd_step(state0, x[:, 0], dt[:, 0], a_log,
                                Bm[:, 0], Cm[:, 0])
        return y[:, None], state
    if use_pallas:
        from repro.kernels.mamba2.kernel import ssd_pallas
        return ssd_pallas(x, dt, a_log, Bm, Cm, state0=state0, chunk=chunk)
    return ref.ssd_chunked(x, dt, a_log, Bm, Cm, state0=state0, chunk=chunk)
