from repro.configs.base import (ALL_SHAPES, SHAPES, ModelConfig, ShapeConfig,
                                get_config, list_archs, shape_applicable)
