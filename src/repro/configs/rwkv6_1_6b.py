"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892].
O(1) per-token state -> runs the long_500k decode shape.
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        num_layers=24, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=7168, vocab_size=65536, mlp="rwkv_channel_mix",
        rwkv_head_dim=64,
    )
