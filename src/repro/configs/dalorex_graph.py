"""The paper's own workload presets: graph/sparse datasets x tile grids.

These drive examples/graph_analytics.py and the fig5-8 benchmarks; the
RMAT scales mirror the paper's synthetic datasets (Section IV-A), clipped
to container-feasible sizes (the paper's RMAT-22/26 need tens of GB).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    name: str
    scale: int            # RMAT scale (V = 2^scale)
    edge_factor: int = 10
    tiles: int = 16       # emulated Dalorex grid size
    apps: tuple = ("bfs", "sssp", "pagerank", "wcc", "spmv")
    # engine execution backend ("xla" | "pallas"): the Pallas tile-grid
    # kernels are bit-identical, so presets differ only in what the run
    # exercises (interpret-mode kernel coverage vs plain XLA tracing)
    backend: str = "xla"
    # NoC fabric + placement: "hier" presets cut the grid into
    # ndies (= (ndies_y, ndies_x)) dies and pair the fabric with a
    # die-local placement so partitions stay die-resident
    noc: str = "ideal"
    ndies: tuple = (1, 1)
    placement: str = "low_order"


PRESETS = {
    # laptop-scale stand-ins for the paper's datasets
    "rmat-small": GraphWorkload("rmat-small", scale=10),
    "rmat-medium": GraphWorkload("rmat-medium", scale=14),
    "rmat-large": GraphWorkload("rmat-large", scale=16, tiles=64),
    # amazon-like: V=262k, E~1.2M -> scale 18 ef 5 approximates the shape
    "amazon-like": GraphWorkload("amazon-like", scale=18, edge_factor=5,
                                 tiles=64),
    # the tile-grid kernel path end to end (kernels/engine, interpret mode)
    "rmat-small-pallas": GraphWorkload("rmat-small-pallas", scale=10,
                                       backend="pallas"),
    # the multi-die composition: an 8x8 grid as 2x2 dies of 4x4 meshes,
    # die-local placement (the shape the paper's >16k-tile scaling story
    # implies; DESIGN.md "Hierarchical NoC")
    "rmat-hier": GraphWorkload("rmat-hier", scale=12, tiles=64,
                               noc="hier", ndies=(2, 2),
                               placement="low_order_dielocal"),
}


def get_workload(name: str) -> GraphWorkload:
    return PRESETS[name]
