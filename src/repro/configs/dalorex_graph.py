"""The paper's own workload presets: graph/sparse datasets x tile grids.

These drive examples/graph_analytics.py and the fig5-8 benchmarks; the
RMAT scales mirror the paper's synthetic datasets (Section IV-A), clipped
to container-feasible sizes (the paper's RMAT-22/26 need tens of GB).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    name: str
    scale: int            # RMAT scale (V = 2^scale)
    edge_factor: int = 10
    tiles: int = 16       # emulated Dalorex grid size
    apps: tuple = ("bfs", "sssp", "pagerank", "wcc", "spmv")
    # engine execution backend ("xla" | "pallas"): the Pallas tile-grid
    # kernels are bit-identical, so presets differ only in what the run
    # exercises (interpret-mode kernel coverage vs plain XLA tracing)
    backend: str = "xla"
    # NoC fabric + placement: "hier" presets cut the grid into
    # ndies (= (ndies_y, ndies_x)) dies and pair the fabric with a
    # die-local placement so partitions stay die-resident
    noc: str = "ideal"
    ndies: tuple = (1, 1)
    placement: str = "low_order"
    # memory space of the tile's edge shard (repro.mem): "vmem" keeps the
    # shard word-random resident; "hbm" streams it through double-buffered
    # segment-DMA windows of ``hbm_window`` elements (0 = auto-size to the
    # next pow2 >= max_t2) — bit-identical values, per-space pricing
    edge_space: str = "vmem"
    hbm_window: int = 0
    # telemetry-driven adaptive placement (repro.place): relabel hot
    # vertices at epoch/query boundaries, at most ``adapt_budget`` moved
    # vertices per plan, every ``adapt_every`` epochs/batches
    adapt: bool = False
    adapt_every: int = 4
    adapt_budget: int = 64


PRESETS = {
    # laptop-scale stand-ins for the paper's datasets
    "rmat-small": GraphWorkload("rmat-small", scale=10),
    "rmat-medium": GraphWorkload("rmat-medium", scale=14),
    "rmat-large": GraphWorkload("rmat-large", scale=16, tiles=64),
    # amazon-like: V=262k, E~1.2M -> scale 18 ef 5 approximates the shape
    "amazon-like": GraphWorkload("amazon-like", scale=18, edge_factor=5,
                                 tiles=64),
    # the tile-grid kernel path end to end (kernels/engine, interpret mode)
    "rmat-small-pallas": GraphWorkload("rmat-small-pallas", scale=10,
                                       backend="pallas"),
    # the multi-die composition: an 8x8 grid as 2x2 dies of 4x4 meshes,
    # die-local placement (the shape the paper's >16k-tile scaling story
    # implies; DESIGN.md "Hierarchical NoC")
    "rmat-hier": GraphWorkload("rmat-hier", scale=12, tiles=64,
                               noc="hier", ndies=(2, 2),
                               placement="low_order_dielocal"),
    # rmat-hier with the trace -> placement loop closed: epoch/query
    # boundaries migrate hot vertices die-aware within the budget
    # (DESIGN.md "Adaptive placement"; benchmarks/fig15_adaptive.py)
    "rmat-hier-adapt": GraphWorkload("rmat-hier-adapt", scale=12, tiles=64,
                                     noc="hier", ndies=(2, 2),
                                     placement="low_order_dielocal",
                                     adapt=True, adapt_every=2,
                                     adapt_budget=128),
    # HBM-resident edge shards (DESIGN.md "Memory spaces"): the per-tile
    # edge segments stream through double-buffered segment DMA instead of
    # assuming the shard fits the tile's VMEM — the beyond-VMEM scaling
    # path (triangles pins its shard to VMEM, so the apps here are the
    # streaming-compatible five + kcore)
    "rmat-small-hbm": GraphWorkload("rmat-small-hbm", scale=10,
                                    edge_space="hbm"),
    # the strong-scaling shape: a shard too big for a paper-era tile SRAM,
    # end to end out of HBM
    "rmat-large-hbm": GraphWorkload("rmat-large-hbm", scale=16, tiles=64,
                                    edge_space="hbm", hbm_window=128),
}


def get_workload(name: str) -> GraphWorkload:
    return PRESETS[name]
