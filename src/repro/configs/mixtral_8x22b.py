"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, sliding-window attn.

56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768
[arXiv:2401.04088].  SWA gives a bounded decode cache, so this MoE runs the
long_500k shape too.
"""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, mlp="swiglu",
        num_experts=8, experts_per_tok=2, sliding_window=4096,
    )
