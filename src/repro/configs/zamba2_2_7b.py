"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242].  A single shared-weight attention block is applied every
6 layers (weights reused — Zamba's signature trick); the Mamba2 state is
O(1) per token -> runs long_500k.
"""
from repro.configs.base import ModelConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=10240, vocab_size=32000, mlp="swiglu",
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
        sliding_window=4096,  # shared attn blocks use a bounded window @500k
    )
