"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32 = MHA) d_ff=8192 vocab=2048 [arXiv:2306.05284].
The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings.  vocab=2048 is too small for routed embedding
to pay off (a2a setup cost > replicated-table gather) — the config runs
WITHOUT the Dalorex technique (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=2048, mlp="gelu",
        frontend="audio", routed_embedding=False,
    )
