"""internvl2-76b [vlm] — InternViT frontend (stub) + InternLM2-76B backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings which are prepended to the token stream.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-76b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, mlp="swiglu",
        rope_theta=1e6, frontend="vision", num_patches=256,
    )
