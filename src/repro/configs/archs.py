"""Import all architecture configs so they self-register."""
from repro.configs import (granite_34b, granite_3_2b, internlm2_20b,  # noqa
                           internvl2_76b, mixtral_8x22b,
                           moonshot_v1_16b_a3b, musicgen_large, nemotron_4_15b,
                           rwkv6_1_6b, zamba2_2_7b)
