"""Architecture configs: one dataclass describes every assigned family.

Every config is selectable via ``--arch <id>`` in the launchers; ``reduced()``
yields the CPU-smoke-test variant of the same family (small widths, few
layers/experts) exercised by tests; the FULL config is only ever lowered
abstractly by the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int            # 0 for attention-free families
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    mlp: str = "swiglu"       # swiglu | squared_relu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0   # moonlight: leading dense layer(s)
    # --- attention extras ---
    sliding_window: int = 0       # 0 = full attention
    # --- SSM / hybrid ---
    ssm_state: int = 0            # Mamba2 N (zamba2) — 0 for non-SSM
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0           # zamba2: shared attn block every k layers
    rwkv_head_dim: int = 64
    # --- modality frontend stub ---
    frontend: str = "none"        # none | vision | audio
    num_patches: int = 256        # vision stub: patch embeddings per image
    # --- technique ---
    routed_embedding: bool = True  # Dalorex vocab-routed embedding lookup
    # ring (context-parallel) attention for train/prefill on a mesh —
    # §Perf train iteration B; falls back to gather-style when the model
    # axis does not divide the sequence
    context_parallel: bool = True
    # --- numerics ---
    dtype: str = "bfloat16"
    # --- lowering ---
    # scan_unroll=True unrolls the layer stack + loss chunks: used by the
    # roofline PROBE lowering so HLO flop/byte/collective counters (which see
    # a while body once) become exact; full-config compiles keep scans rolled
    # for O(1) HLO size.
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (bounded per-token state)"""
        return (self.family in ("ssm", "hybrid")
                or (self.sliding_window > 0))

    def reduced(self) -> "ModelConfig":
        """Same family, smoke-test size (runs a step on 1 CPU device)."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4 if self.attn_every == 0
                           else 2 * max(self.attn_every, 1)),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads,
                             min(self.num_heads, 4)) if self.num_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.num_experts == 0 else 64,
            vocab_size=512,
            num_experts=min(self.num_experts, 8),
            experts_per_tok=min(self.experts_per_tok, 2),
            moe_capacity_factor=4.0,  # drop-free so smoke tests are exact
            sliding_window=min(self.sliding_window, 64) or 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            rwkv_head_dim=32,
            num_patches=8,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.hd
        n = V * d  # embedding
        n += V * d  # lm head (untied)
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            per_layer += 4 * d * d + d * d  # r,k,v,o + gate
            per_layer += 2 * d * ff  # channel mix (k, v)... r too
            per_layer += d * ff
        else:
            if self.num_heads:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                per_layer += q + kv + o
            if self.num_experts:
                mult = 3 if self.mlp == "swiglu" else 2
                per_layer += self.num_experts * mult * d * ff
                per_layer += d * self.num_experts  # router
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                per_layer += mult * d * ff
            if self.family == "hybrid":
                # mamba2 block: in_proj (x,z,B,C,dt) + out_proj
                din = self.ssm_expand * d
                per_layer += d * (2 * din + 2 * self.ssm_state) + din * d
        n += L * per_layer
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only selected experts)."""
        if not self.num_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        mult = 3 if self.mlp == "swiglu" else 2
        total = self.param_count()
        moe_all = L * self.num_experts * mult * d * ff
        moe_active = L * self.experts_per_tok * mult * d * ff
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the arch modules lazily so `configs.<id>` self-registers
        from repro.configs import archs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from repro.configs import archs  # noqa: F401
    return sorted(_REGISTRY)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (skip; " \
                      "pure full-attention arch)"
    return True, ""
