"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 64 experts top-6, tiny d_ff.

48L d_model=2048 16H (kv=16 = MHA) expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B].  The most routing-intensive cell: 6-way
dispatch over 64 experts each layer — the Dalorex showcase.  Layer 0 is
dense (as in Moonlight).
"""
from repro.configs.base import ModelConfig, register


@register("moonshot-v1-16b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163840, mlp="swiglu",
        num_experts=64, experts_per_tok=6, first_dense_layers=1,
    )
