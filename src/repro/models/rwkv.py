"""RWKV6 "Finch" blocks: time-mix (WKV with data-dependent decay) +
channel-mix.  Attention-free: O(1) decode state per layer — this family runs
the long_500k shape.

Weights follow the Finch structure: static token-shift lerps per projection,
a LoRA producing the per-channel data-dependent decay ``w_t``, and the
per-channel bonus ``u``.  The recurrence itself lives in
kernels/rwkv6 (ref.py oracle, chunked jnp, Pallas TPU kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ops as wkv_ops
from repro.models.layers import rms_norm
from repro.parallel.sharding import ParamSpec, gathered, lsc

W_LORA_RANK = 32


def rwkv_block_specs(d: int, ff: int, head_dim: int, dtype: str):
    H = d // head_dim
    return {
        "ln1": ParamSpec((d,), (None,), "float32", init="ones"),
        "ln2": ParamSpec((d,), (None,), "float32", init="ones"),
        # time-mix
        "mu": ParamSpec((5, d), (None, None), "float32", init="zeros"),
        "w_r": ParamSpec((d, d), ("fsdp", "heads"), dtype),
        "w_k": ParamSpec((d, d), ("fsdp", "heads"), dtype),
        "w_v": ParamSpec((d, d), ("fsdp", "heads"), dtype),
        "w_g": ParamSpec((d, d), ("fsdp", "heads"), dtype),
        "w_o": ParamSpec((d, d), ("heads", "fsdp"), dtype),
        "w0": ParamSpec((d,), (None,), "float32", init="zeros"),
        "w_lora_a": ParamSpec((d, W_LORA_RANK), (None, None), "float32"),
        "w_lora_b": ParamSpec((W_LORA_RANK, d), (None, None), "float32",
                              init="zeros"),
        "u": ParamSpec((H, head_dim), (None, None), "float32", init="zeros"),
        "ln_x": ParamSpec((d,), (None,), "float32", init="ones"),
        # channel-mix
        "mu_c": ParamSpec((2, d), (None, None), "float32", init="zeros"),
        "w_ck": ParamSpec((d, ff), ("fsdp", "mlp"), dtype),
        "w_cv": ParamSpec((ff, d), ("mlp", "fsdp"), dtype),
        "w_cr": ParamSpec((d, d), ("fsdp", None), dtype),
    }


def _shift(x, last):
    """Token shift: x_{t-1} (last: (B, d) carry for the first position)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _time_mix(p, x, last_x, wkv_state, head_dim: int, use_pallas: bool):
    B, S, d = x.shape
    H = d // head_dim
    xs = _shift(x, last_x)
    mu = p["mu"].astype(x.dtype)
    lerp = x[None] + (xs - x)[None] * mu[:, None, None]  # (5, B, S, d)
    lr, lk, lv, lw, lg = lerp

    train = S > 1
    gw = (lambda w: gathered(w, None, None)) if train else (lambda w: w)
    r = jnp.einsum("bsd,de->bse", lr, gw(p["w_r"]),
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,de->bse", lk, gw(p["w_k"]),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,de->bse", lv, gw(p["w_v"]),
                   preferred_element_type=jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", lg, gw(p["w_g"]),
                               preferred_element_type=jnp.float32))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x)))
    lora = jnp.einsum("bsd,dr,re->bse", lw.astype(jnp.float32),
                      p["w_lora_a"], p["w_lora_b"])
    w_log = -jnp.exp(p["w0"][None, None] + jnp.tanh(lora))
    # clip so chunk-local cumulated decays stay in fp32 exp range (a decay
    # below e^-4 per step is indistinguishable from 0 within 2-3 steps)
    w_log = jnp.clip(w_log, -4.0, -1e-6)

    shape4 = (B, S, H, head_dim)
    y, wkv_state = wkv_ops.wkv6(
        r.reshape(shape4), k.reshape(shape4), v.reshape(shape4),
        w_log.reshape(shape4), p["u"], state0=wkv_state,
        use_pallas=use_pallas)
    y = y.reshape(B, S, d)
    # per-head group norm
    yh = y.reshape(B, S, H, head_dim)
    yh = yh * jax.lax.rsqrt(
        jnp.mean(jnp.square(yh), axis=-1, keepdims=True) + 1e-5)
    y = yh.reshape(B, S, d) * p["ln_x"][None, None]
    out = jnp.einsum("bsd,de->bse", (y * g).astype(x.dtype), gw(p["w_o"]),
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype), x[:, -1], wkv_state


def _channel_mix(p, x, last_x):
    xs = _shift(x, last_x)
    mu = p["mu_c"].astype(x.dtype)
    lk = x + (xs - x) * mu[0][None, None]
    lr = x + (xs - x) * mu[1][None, None]
    train = x.shape[1] > 1
    gw = (lambda w: gathered(w, None, None)) if train else (lambda w: w)
    kk = jnp.einsum("bsd,df->bsf", lk, gw(p["w_ck"]),
                    preferred_element_type=jnp.float32)
    kk = jnp.square(jax.nn.relu(kk)).astype(x.dtype)
    from repro.models.layers import _h_constraint
    kk = _h_constraint(kk, decode=x.shape[1] == 1)
    vv = jnp.einsum("bsf,fd->bsd", kk, gw(p["w_cv"]),
                    preferred_element_type=jnp.float32)
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", lr, gw(p["w_cr"]),
                                   preferred_element_type=jnp.float32))
    return (rr * vv).astype(x.dtype), x[:, -1]


def rwkv_block(p, x, state, head_dim: int, eps: float, use_pallas: bool):
    """x: (B, S, d).  state = (last_tm (B,d), last_cm (B,d),
    wkv (B,H,K,K)) or None (training: zero init, discard)."""
    B, S, d = x.shape
    H = d // head_dim
    if state is None:
        last_tm = jnp.zeros((B, d), x.dtype)
        last_cm = jnp.zeros((B, d), x.dtype)
        wkv = jnp.zeros((B, H, head_dim, head_dim), jnp.float32)
    else:
        last_tm, last_cm, wkv = state
    h = rms_norm(x, p["ln1"], eps)
    att, last_tm, wkv = _time_mix(p, h, last_tm, wkv, head_dim, use_pallas)
    x = x + att
    h = rms_norm(x, p["ln2"], eps)
    cm, last_cm = _channel_mix(p, h, last_cm)
    x = x + cm
    return x, (last_tm, last_cm, wkv)
