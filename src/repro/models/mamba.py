"""Mamba2 (SSD) blocks for the zamba2 hybrid.

The selective-state recurrence lives in kernels/mamba2 (ref oracle, chunked
jnp, Pallas TPU kernel).  O(1) decode state (H, P, N) per layer — the hybrid
runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba2 import ops as ssd_ops
from repro.models.layers import rms_norm
from repro.parallel.sharding import ParamSpec, lsc

CONV_K = 4


def mamba_block_specs(d: int, expand: int, head_dim: int, N: int, dtype: str):
    din = expand * d
    H = din // head_dim
    proj_out = 2 * din + 2 * N + H  # z, x, B, C, dt
    return {
        "norm": ParamSpec((d,), (None,), "float32", init="ones"),
        "in_proj": ParamSpec((d, proj_out), ("fsdp", "heads"), dtype),
        "conv_w": ParamSpec((CONV_K, din + 2 * N), (None, None), "float32"),
        "conv_b": ParamSpec((din + 2 * N,), (None,), "float32", init="zeros"),
        "a_log": ParamSpec((H,), (None,), "float32", init="zeros"),
        "d_skip": ParamSpec((H,), (None,), "float32", init="ones"),
        "dt_bias": ParamSpec((H,), (None,), "float32", init="zeros"),
        "norm_g": ParamSpec((din,), (None,), "float32", init="ones"),
        "out_proj": ParamSpec((din, d), ("heads", "fsdp"), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, kernel CONV_K.  x: (B, S, C)."""
    pad = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(CONV_K))
    return out + b[None, None]


def _conv_step(conv_state, xt, w, b):
    """conv_state: (B, CONV_K-1, C) previous inputs; xt: (B, C)."""
    full = jnp.concatenate([conv_state, xt[:, None]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", full, w) + b[None]
    return full[:, 1:], out


def mamba_block(p, x, state, cfg, use_pallas: bool):
    """x: (B, S, d).  state = (conv (B,K-1,C), ssd (B,H,P,N)) or None."""
    B, S, d = x.shape
    din = cfg.ssm_expand * d
    P_, N = cfg.ssm_head_dim, cfg.ssm_state
    H = din // P_
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)

    if state is None or S > 1:
        # train / prefill: full causal conv over the sequence; the carry-out
        # conv state is the last K-1 raw inputs, the SSD state falls out of
        # the chunked recurrence below
        if state is None:
            ssd_state = jnp.zeros((B, H, P_, N), jnp.float32)
        else:
            _, ssd_state = state
        raw = xbc
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        tail = jnp.zeros((B, CONV_K - 1, din + 2 * N), x.dtype)
        take = min(S, CONV_K - 1)
        conv_state = tail.at[:, CONV_K - 1 - take:].set(
            raw[:, S - take:].astype(x.dtype))
    else:
        conv_state, ssd_state = state
        conv_state, xbc1 = _conv_step(conv_state, xbc[:, 0], p["conv_w"],
                                      p["conv_b"])
        xbc = xbc1[:, None]
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])

    xh = xs.reshape(B, S, H, P_).astype(jnp.float32)
    y, ssd_state = ssd_ops.ssd(
        xh, dt, p["a_log"], Bm.astype(jnp.float32), Cm.astype(jnp.float32),
        state0=ssd_state, use_pallas=use_pallas)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, din)
    # gated RMSNorm (mamba2)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["norm_g"], cfg.norm_eps)
    if S == 1:  # decode: keep din sharded -> slice the resident out_proj
        y = lsc(y, "batch", None, "heads")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.float32)
    return x + out.astype(x.dtype), (conv_state, ssd_state)
