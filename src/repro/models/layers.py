"""Shared LM building blocks: RMSNorm, RoPE, GQA attention, MLP variants.

Attention is *blockwise* (two-level scan with online softmax — the XLA-level
flash pattern) so train/prefill memory is O(S·block) not O(S^2); the Pallas
flash kernel (kernels/flash_attention) is the TPU-optimized drop-in for the
same math.  All matmuls accumulate in fp32 (``preferred_element_type``);
norms run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import gathered, lsc

NEG_INF = -1e30


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Blockwise causal attention (train / prefill).
# --------------------------------------------------------------------------

def _attn_block(q, k, v, qpos, kpos, window):
    """One (q-block, kv-block) tile.  q: (B, qb, Hkv, G, hd);
    k/v: (B, kb, Hkv, hd).  Returns (scores_max, exp_sum, acc) pieces."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = kpos[None, :] <= qpos[:, None]  # causal
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def blockwise_attention(q, k, v, positions, window: int = 0,
                        q_block: int = 512, kv_block: int = 512):
    """Causal (optionally windowed) attention, memory O(S·block).

    q: (B, S, H, hd); k, v: (B, S, Hkv, hd); positions: (S,).
    Two-level scan: outer over q blocks, inner over kv blocks, carrying the
    online-softmax (m, l, acc) triple — the flash-attention recurrence.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    nq, nk = S // q_block, S // kv_block
    assert S % q_block == 0 and S % kv_block == 0

    qg = q.reshape(B, nq, q_block, Hkv, G, hd).swapaxes(0, 1)
    kg = k.reshape(B, nk, kv_block, Hkv, hd).swapaxes(0, 1)
    vg = v.reshape(B, nk, kv_block, Hkv, hd).swapaxes(0, 1)
    pg = positions.reshape(nq, q_block)

    def outer(_, qi_and_pos):
        qi, qpos, iq = qi_and_pos

        def inner(carry, ki_vi_pos):
            m, l, acc = carry
            ki, vi, kpos, ik = ki_vi_pos
            s = _attn_block(qi, ki, vi, qpos, kpos, window)
            # skip tiles strictly above the diagonal (saves nothing in FLOPs
            # under scan, but keeps the math exact for any block shape)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p,
                            vi.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0),
            (kg, vg, positions.reshape(nk, kv_block),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, G, qb, hd) -> (B, qb, H, hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(outer, None, (qg, pg, jnp.arange(nq)))
    # (nq, B, q_block, H, hd) -> (B, S, H, hd)
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, cache_positions, q_position,
                     window: int = 0):
    """Single-token attention against a (ring-buffered) KV cache.

    q: (B, 1, H, hd); caches: (B, C, Hkv, hd); cache_positions: (B, C) actual
    sequence positions held in each slot (-1 = empty).  The cache slot axis C
    is sequence-sharded over the model axis (flash-decode): each device scans
    only its slice, and the (m, l, acc) softmax merge happens in fp32 via the
    psums XLA inserts — the Dalorex move: the cache (data) never moves, the
    query (task) visits it.
    """
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bchd->bhgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = (cache_positions >= 0) & (cache_positions <= q_position[:, None])
    if window:
        mask &= cache_positions > q_position[:, None] - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p, v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLP variants.
# --------------------------------------------------------------------------

def _h_constraint(h, decode: bool):
    """Hidden-state constraint: train/prefill keep the sequence sharded
    (weights gathered, ZeRO-TP); decode keeps ff sharded so the w_down
    contraction SLICES the resident weight and psums the tiny partial,
    instead of gathering the weight per generated token."""
    if decode:
        return lsc(h, "batch", None, "mlp")
    return lsc(h, "batch", "seq", None)


def mlp_apply(params, x, kind: str):
    """x: (..., d).  Weights are laid out (d, ff) / (ff, d).

    TRAIN/PREFILL (many tokens/device): weights are pre-gathered in bf16
    behind an optimization barrier (§Perf iter A3) — seq-local compute with
    weight-gathering beats activation gathers, and the barrier stops the
    SPMD partitioner from all-gathering the fp32-upcast copy instead.
    DECODE (one token): weights stay sharded-resident (model-TP,
    DECODE_RULES); gathering them per generated token is the pathology
    §Perf iter 1 removed."""
    decode = x.shape[-2] == 1

    def gw(w):
        return w if decode else gathered(w, None, None)

    w_up = gw(params["w_up"])
    w_down = gw(params["w_down"])
    if kind == "swiglu":
        w_gate = gw(params["w_gate"])
        g = jnp.einsum("...d,df->...f", x, w_gate,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("...d,df->...f", x, w_up,
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        h = _h_constraint(h, decode)
    elif kind == "squared_relu":
        u = jnp.einsum("...d,df->...f", x, w_up,
                       preferred_element_type=jnp.float32)
        h = jnp.square(jax.nn.relu(u)).astype(x.dtype)
        h = _h_constraint(h, decode)
    elif kind == "gelu":
        u = jnp.einsum("...d,df->...f", x, w_up,
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(u).astype(x.dtype)
        h = _h_constraint(h, decode)
    else:
        raise ValueError(kind)
    out = jnp.einsum("...f,fd->...d", h, w_down,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def mlp_specs(d: int, ff: int, kind: str, dtype: str):
    from repro.parallel.sharding import ParamSpec
    if kind == "swiglu":
        return {
            "w_gate": ParamSpec((d, ff), ("fsdp", "mlp"), dtype),
            "w_up": ParamSpec((d, ff), ("fsdp", "mlp"), dtype),
            "w_down": ParamSpec((ff, d), ("mlp", "fsdp"), dtype),
        }
    return {
        "w_up": ParamSpec((d, ff), ("fsdp", "mlp"), dtype),
        "w_down": ParamSpec((ff, d), ("mlp", "fsdp"), dtype),
    }
