"""Model builder: every assigned architecture family from one config.

Families
  dense / vlm / audio — pre-norm GQA transformer (vlm/audio prepend/add a
      stub-frontend projection per the assignment).
  moe      — dense attention + Dalorex-routed expert FFN (core/moe.py);
             optional leading dense layers (moonlight).
  ssm      — RWKV6 stack (attention-free).
  hybrid   — zamba2: super-blocks of [shared attention + k Mamba2 layers];
             the attention block's WEIGHTS are shared across super-blocks
             (Zamba's trick), each application has its own KV cache slot.

Layer stacks are ``lax.scan``-ned (O(1) HLO size at 88 layers), bodies are
``jax.checkpoint``-ed for remat.  Decode uses ring-buffered KV caches
(slot = pos mod C) sequence-sharded over the model axis — flash-decode with
the Dalorex flavor: cache data never moves, the query visits it.

Embedding uses the routed vocab-sharded lookup (core/embedding.py) when the
config enables the technique; the LM head computes the loss against
vocab-sharded logits in sequence chunks, so full logits are never
materialized.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.embedding import embed_lookup, padded_vocab
from repro.core.moe import moe_block, moe_param_specs
from repro.models.layers import (blockwise_attention, decode_attention,
                                 mlp_apply, mlp_specs, rms_norm, rope)
from repro.models.mamba import CONV_K, mamba_block, mamba_block_specs
from repro.models.rwkv import rwkv_block, rwkv_block_specs
from repro.parallel.sharding import ParamSpec, current_mesh, gathered, lsc

FRONTEND_DIM = {"vision": 1024, "audio": 128}


# --------------------------------------------------------------------------
# Parameter specs.
# --------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig):
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "ln": ParamSpec((d,), (None,), "float32", init="ones"),
        "wq": ParamSpec((d, H, hd), ("fsdp", "heads", None), cfg.dtype),
        "wk": ParamSpec((d, Hkv, hd), ("fsdp", "kv_heads", None), cfg.dtype),
        "wv": ParamSpec((d, Hkv, hd), ("fsdp", "kv_heads", None), cfg.dtype),
        "wo": ParamSpec((H, hd, d), ("heads", None, "fsdp"), cfg.dtype),
    }


def _dense_block_specs(cfg: ModelConfig):
    s = {"attn": _attn_specs(cfg),
         "ln2": ParamSpec((cfg.d_model,), (None,), "float32", init="ones"),
         "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, cfg.dtype)}
    return s


def _moe_block_specs(cfg: ModelConfig, M: int):
    s = {"attn": _attn_specs(cfg),
         "ln2": ParamSpec((cfg.d_model,), (None,), "float32", init="ones"),
         "moe": moe_param_specs(cfg.d_model, cfg.d_ff, cfg.num_experts, M,
                                cfg.mlp, cfg.dtype)}
    return s


def _stack(specs, n: int):
    """Add a leading scanned-layer axis to every leaf spec."""
    def one(s: ParamSpec):
        return ParamSpec((n,) + s.shape, (None,) + s.axes, s.dtype,
                         s.init, s.scale)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_mesh_size(model_axis: str = "model") -> int:
    mesh = current_mesh()
    return mesh.shape[model_axis] if mesh is not None else 1


def abstract_params(cfg: ModelConfig, moe_shards: int | None = None):
    """ParamSpec tree for the whole model (dry-run lowers this directly)."""
    d = cfg.d_model
    M = moe_shards if moe_shards is not None else model_mesh_size()
    v_pad = padded_vocab(cfg.vocab_size, max(M, 1))
    vocab_axis = "vocab" if cfg.routed_embedding else None
    p = {
        "embed": ParamSpec((v_pad, d), (vocab_axis, None), cfg.dtype,
                           init="embed", scale=0.02),
        "final_norm": ParamSpec((d,), (None,), "float32", init="ones"),
        "lm_head": ParamSpec((d, v_pad), ("fsdp", "vocab"), cfg.dtype),
    }
    if cfg.frontend in FRONTEND_DIM:
        p["frontend_proj"] = ParamSpec(
            (FRONTEND_DIM[cfg.frontend], d), (None, "fsdp"), cfg.dtype)
    L = cfg.num_layers
    if cfg.family == "ssm":
        p["blocks"] = _stack(
            rwkv_block_specs(d, cfg.d_ff, cfg.rwkv_head_dim, cfg.dtype), L)
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        assert L % k == 0, (L, k)
        p["shared_attn"] = {
            **_attn_specs(cfg),
            "ln2": ParamSpec((d,), (None,), "float32", init="ones"),
            "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp, cfg.dtype),
        }
        p["blocks"] = _stack(_stack(
            mamba_block_specs(d, cfg.ssm_expand, cfg.ssm_head_dim,
                              cfg.ssm_state, cfg.dtype), k), L // k)
    elif cfg.family == "moe":
        fd = cfg.first_dense_layers
        if fd:
            p["first_blocks"] = _stack(_dense_block_specs(cfg), fd)
        p["blocks"] = _stack(_moe_block_specs(cfg, max(M, 1)), L - fd)
    else:  # dense / vlm / audio
        p["blocks"] = _stack(_dense_block_specs(cfg), L)
    return p


def init_params(key, cfg: ModelConfig):
    from repro.parallel.sharding import init_tree
    return init_tree(key, abstract_params(cfg))


# --------------------------------------------------------------------------
# Decode cache.
# --------------------------------------------------------------------------

class Cache(NamedTuple):
    pos: jax.Array                 # () int32 — tokens decoded so far
    attn_k: jax.Array | None       # (n_attn, B, C, Hkv, hd)
    attn_v: jax.Array | None
    rwkv: tuple | None             # (last_tm, last_cm, wkv) leading (L, B,..)
    mamba: tuple | None            # (conv, ssd) leading (L//k, k, B, ...)


def cache_slots(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int):
    """ParamSpec tree for the decode cache (dry-run input)."""
    d, Hkv, hd = cfg.d_model, cfg.num_kv_heads, cfg.hd
    C = cache_slots(cfg, seq_len)
    L = cfg.num_layers
    pos = ParamSpec((), (), "int32", init="zeros")
    attn_k = attn_v = rwkv = mamba = None
    kv_axes = (None, "batch", "kv_seq", None, None)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        attn_k = ParamSpec((L, batch, C, Hkv, hd), kv_axes, cfg.dtype,
                           init="zeros")
        attn_v = ParamSpec((L, batch, C, Hkv, hd), kv_axes, cfg.dtype,
                           init="zeros")
    elif cfg.family == "ssm":
        H = d // cfg.rwkv_head_dim
        K = cfg.rwkv_head_dim
        rwkv = (
            ParamSpec((L, batch, d), (None, "batch", None), cfg.dtype,
                      init="zeros"),
            ParamSpec((L, batch, d), (None, "batch", None), cfg.dtype,
                      init="zeros"),
            ParamSpec((L, batch, H, K, K),
                      (None, "batch", "heads", None, None), "float32",
                      init="zeros"),
        )
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        n_sb = L // k
        attn_k = ParamSpec((n_sb, batch, C, Hkv, hd), kv_axes, cfg.dtype,
                           init="zeros")
        attn_v = ParamSpec((n_sb, batch, C, Hkv, hd), kv_axes, cfg.dtype,
                           init="zeros")
        din = cfg.ssm_expand * d
        H = din // cfg.ssm_head_dim
        mamba = (
            ParamSpec((n_sb, k, batch, CONV_K - 1, din + 2 * cfg.ssm_state),
                      (None, None, "batch", None, None), cfg.dtype,
                      init="zeros"),
            ParamSpec((n_sb, k, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                      (None, None, "batch", "heads", None, None), "float32",
                      init="zeros"),
        )
    return Cache(pos, attn_k, attn_v, rwkv, mamba)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    from repro.parallel.sharding import init_tree
    spec = abstract_cache(cfg, batch, seq_len)
    return init_tree(jax.random.PRNGKey(0), spec)


def _slot_positions(pos, C: int):
    """Sequence position stored in each ring slot (-1 = empty)."""
    i = jnp.arange(C, dtype=jnp.int32)
    cand = pos - 1 - ((pos - 1 - i) % C)
    return jnp.where(cand >= 0, cand, -1)


# --------------------------------------------------------------------------
# Attention block (shared by dense / moe / vlm / audio / zamba-shared).
# --------------------------------------------------------------------------

def _use_ring(cfg: ModelConfig, S: int, kv_cache) -> bool:
    """Context-parallel (ring) attention for train AND prefill on a mesh
    whose model axis divides the sequence (the attention compute is
    cache-independent; prefill's cache write happens from kk/vv upstream).
    Decode (S==1) keeps the flash-decode cache layout."""
    if S == 1 or not cfg.context_parallel or not cfg.num_heads:
        return False
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return False
    M = mesh.shape["model"]
    return S % M == 0 and S >= M and M > 1


def _attn_apply(p, x, cfg: ModelConfig, kv_cache, pos):
    """x: (B, S, d).  kv_cache: None (train) or (k, v) ring buffers.

    Returns (out (B,S,d), new_kv or None)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    # (§Perf train iter A, REFUTED: pre-gathering the sequence for Megatron
    # -SP style TP costs more than weight-gathering at 65k tokens/device —
    # see EXPERIMENTS.md.  The projections run on the seq-sharded stream.)
    ring = _use_ring(cfg, S, kv_cache)
    if ring:
        # context parallelism: weights fully gathered in bf16 (barrier pins
        # the collective below the fp32 convert), sequence stays sharded
        wq = gathered(p["wq"], None, None, None)
        wk = gathered(p["wk"], None, None, None)
        wv = gathered(p["wv"], None, None, None)
    else:
        wq, wk, wv = p["wq"], p["wk"], p["wv"]
    q = jnp.einsum("bsd,dhk->bshk", h, wq,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    kk = jnp.einsum("bsd,dhk->bshk", h, wk,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    vv = jnp.einsum("bsd,dhk->bshk", h, wv,
                    preferred_element_type=jnp.float32).astype(x.dtype)

    if kv_cache is None or S > 1:  # train / prefill
        positions = jnp.arange(S, dtype=jnp.int32)
        q = rope(q, positions, cfg.rope_theta)
        kk = rope(kk, positions, cfg.rope_theta)
        if ring:
            from repro.parallel.ring import ring_attention
            q = lsc(q, "batch", "seq", None, None)
            kk = lsc(kk, "batch", "seq", None, None)
            vv = lsc(vv, "batch", "seq", None, None)
            att = ring_attention(q, kk, vv, mesh=current_mesh(),
                                 batch_axes=_batch_axes(),
                                 window=cfg.sliding_window)
        else:
            q = lsc(q, "batch", None, "heads", None)
            # gather seq on the small Hkv tensors FIRST (cheap), then
            # repeat to H heads locally (reference path; grouped on the TPU
            # kernel) — the repeat is free under a heads-sharded layout
            kk = lsc(kk, "batch", None, "kv_heads", None)
            vv = lsc(vv, "batch", None, "kv_heads", None)
            rep = kk.shape[2]
            kf = jnp.repeat(kk, H // rep, axis=2)
            vf = jnp.repeat(vv, H // rep, axis=2)
            kf = lsc(kf, "batch", None, "heads", None)
            vf = lsc(vf, "batch", None, "heads", None)
            att = blockwise_attention(q, kf, vf, positions,
                                      window=cfg.sliding_window)
        new_kv = None
        if kv_cache is not None:  # prefill into the ring cache
            ck, cv = kv_cache
            C = ck.shape[1]
            take = min(S, C)
            slots = (jnp.arange(take, dtype=jnp.int32) + (S - take)) % C
            new_kv = (
                ck.at[:, slots].set(kk[:, S - take:].astype(ck.dtype)),
                cv.at[:, slots].set(vv[:, S - take:].astype(cv.dtype)),
            )
    else:  # decode: one token against the ring cache
        qpos = jnp.full((B,), pos, jnp.int32)
        q = rope(q, qpos[:, None], cfg.rope_theta)
        kk = rope(kk, qpos[:, None], cfg.rope_theta)
        ck, cv = kv_cache
        C = ck.shape[1]
        slot = pos % C
        ck = jax.lax.dynamic_update_slice(ck, kk.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vv.astype(cv.dtype),
                                          (0, slot, 0, 0))
        ck = lsc(ck, "batch", "kv_seq", None, None)
        cv = lsc(cv, "batch", "kv_seq", None, None)
        cpos = jnp.broadcast_to(_slot_positions(pos + 1, C)[None], (B, C))
        att = decode_attention(q, ck, cv, cpos, qpos,
                               window=cfg.sliding_window)
        new_kv = (ck, cv)

    out = jnp.einsum("bshk,hkd->bsd", att, p["wo"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_kv


def _dense_block(p, x, cfg, kv_cache, pos):
    att, new_kv = _attn_apply(p["attn"], x, cfg, kv_cache, pos)
    x = x + att
    x = lsc(x, "batch", "seq", None)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(p["mlp"], h, cfg.mlp)
    x = lsc(x, "batch", "seq", None)
    return x, new_kv, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)


def _moe_block_apply(p, x, cfg, kv_cache, pos, seq_shard, batch_axes):
    att, new_kv = _attn_apply(p["attn"], x, cfg, kv_cache, pos)
    x = x + att
    x = lsc(x, "batch", "seq", None)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux, ovf = moe_block(
        p["moe"], h, E=cfg.num_experts, k=cfg.experts_per_tok,
        ff=cfg.d_ff, mlp=cfg.mlp, batch_axes=batch_axes,
        seq_shard=seq_shard, capacity_factor=cfg.moe_capacity_factor)
    x = x + y
    x = lsc(x, "batch", "seq", None)
    return x, new_kv, aux, ovf


# --------------------------------------------------------------------------
# Forward.
# --------------------------------------------------------------------------

def _batch_axes():
    mesh = current_mesh()
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def forward(params, cfg: ModelConfig, batch: dict, *, cache: Cache = None,
            remat: bool = True, use_pallas: bool = False):
    """Returns (hidden (B,S,d), new_cache, aux dict).

    batch: {"tokens": (B,S)} (+ "patches" for vlm, "frames" for audio).
    cache=None -> training/scoring; cache -> decode/prefill serving.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    d = cfg.d_model
    decoding = cache is not None and S == 1
    batch_axes = _batch_axes()
    seq_shard = not decoding

    emb, ovf_embed = embed_lookup(
        params["embed"], tokens, cfg.routed_embedding,
        batch_axes=batch_axes, seq_shard=seq_shard)
    x = emb
    if cfg.frontend == "vision" and "patches" in batch:
        pe = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(x.dtype),
                        params["frontend_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    elif cfg.frontend == "audio" and "frames" in batch:
        fe = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(x.dtype),
                        params["frontend_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = x + fe
    x = lsc(x, "batch", "seq", None)

    pos = cache.pos if cache is not None else jnp.zeros((), jnp.int32)
    aux_total = jnp.zeros((), jnp.float32)
    ovf_total = ovf_embed
    new_cache = cache

    def ckpt(fn):
        return jax.checkpoint(fn) if remat else fn

    if cfg.family == "ssm":
        def body(x, layer):
            p_l, st = layer
            x, st = rwkv_block(p_l, x, st, cfg.rwkv_head_dim, cfg.norm_eps,
                               use_pallas)
            return x, st
        states = cache.rwkv if cache is not None else None
        if states is None:
            L = cfg.num_layers
            H = d // cfg.rwkv_head_dim
            K = cfg.rwkv_head_dim
            states = (jnp.zeros((L, B, d), x.dtype),
                      jnp.zeros((L, B, d), x.dtype),
                      jnp.zeros((L, B, H, K, K), jnp.float32))
        x, new_states = jax.lax.scan(ckpt(body), x,
                                     (params["blocks"], states),
                                     unroll=cfg.scan_unroll)
        if cache is not None:
            new_cache = cache._replace(rwkv=new_states,
                                       pos=cache.pos + S)
    elif cfg.family == "hybrid":
        k = cfg.attn_every

        def shared_attn(x, kv):
            att, new_kv = _attn_apply(params["shared_attn"], x, cfg, kv, pos)
            x = x + att
            h = rms_norm(x, params["shared_attn"]["ln2"], cfg.norm_eps)
            x = x + mlp_apply(params["shared_attn"]["mlp"], h, cfg.mlp)
            return lsc(x, "batch", "seq", None), new_kv

        if cache is not None:
            def superblock(x, layer):
                p_sb, kv, mst = layer
                x, new_kv = shared_attn(x, kv)

                def inner(x, lyr):
                    p_l, st = lyr
                    x, st = mamba_block(p_l, x, st, cfg, use_pallas)
                    return x, st
                x, new_mst = jax.lax.scan(inner, x, (p_sb, mst),
                                          unroll=cfg.scan_unroll)
                return x, (new_kv, new_mst)
            x, (new_kv, new_mst) = jax.lax.scan(
                ckpt(superblock), x,
                (params["blocks"], (cache.attn_k, cache.attn_v),
                 cache.mamba), unroll=cfg.scan_unroll)
            new_cache = cache._replace(attn_k=new_kv[0], attn_v=new_kv[1],
                                       mamba=new_mst, pos=cache.pos + S)
        else:
            def superblock(x, p_sb):
                x, _ = shared_attn(x, None)

                def inner(x, p_l):
                    x, _ = mamba_block(p_l, x, None, cfg, use_pallas)
                    return x, None
                x, _ = jax.lax.scan(inner, x, p_sb,
                                    unroll=cfg.scan_unroll)
                return x, None
            x, _ = jax.lax.scan(ckpt(superblock), x, params["blocks"],
                                unroll=cfg.scan_unroll)
    else:  # dense / vlm / audio / moe
        is_moe = cfg.family == "moe"
        fd = cfg.first_dense_layers if is_moe else 0

        def run_stage(x, stage_params, kv, moe_stage):
            """kv=None: training (no cache ever built).  kv=(k,v): serving."""
            def apply_block(p_l, x, kv_l):
                if moe_stage:
                    return _moe_block_apply(p_l, x, cfg, kv_l, pos,
                                            seq_shard, batch_axes)
                return _dense_block(p_l, x, cfg, kv_l, pos)

            if kv is None:
                def body(carry, p_l):
                    x, aux, ovf = carry
                    x, _, a, o = apply_block(p_l, x, None)
                    return (x, aux + a, ovf + o), None
                (x, aux, ovf), _ = jax.lax.scan(
                    ckpt(body), (x, jnp.zeros((), jnp.float32),
                                 jnp.zeros((), jnp.int32)), stage_params,
                    unroll=cfg.scan_unroll)
                return x, aux, ovf, None

            def body(carry, layer):
                x, aux, ovf = carry
                p_l, kv_l = layer
                x, new_kv, a, o = apply_block(p_l, x, kv_l)
                return (x, aux + a, ovf + o), new_kv
            (x, aux, ovf), new_kv = jax.lax.scan(
                ckpt(body), (x, jnp.zeros((), jnp.float32),
                             jnp.zeros((), jnp.int32)), (stage_params, kv),
                unroll=cfg.scan_unroll)
            return x, aux, ovf, new_kv

        kv_all = None if cache is None else (cache.attn_k, cache.attn_v)
        nk_parts = []
        if fd:
            kv0 = None if kv_all is None else tuple(a[:fd] for a in kv_all)
            x, a0, o0, nkv0 = run_stage(x, params["first_blocks"], kv0,
                                        False)
            aux_total += a0
            ovf_total += o0
            nk_parts.append(nkv0)
        kv1 = None if kv_all is None else tuple(a[fd:] for a in kv_all)
        x, a1, o1, nkv1 = run_stage(x, params["blocks"], kv1, is_moe)
        aux_total += a1
        ovf_total += o1
        nk_parts.append(nkv1)
        if cache is not None:
            nk = jnp.concatenate([p[0] for p in nk_parts], axis=0) \
                if len(nk_parts) > 1 else nk_parts[0][0]
            nv = jnp.concatenate([p[1] for p in nk_parts], axis=0) \
                if len(nk_parts) > 1 else nk_parts[0][1]
            new_cache = cache._replace(attn_k=nk, attn_v=nv,
                                       pos=cache.pos + S)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = lsc(x, "batch", "seq", None)
    return x, new_cache, {"moe_aux": aux_total, "overflow": ovf_total}


# --------------------------------------------------------------------------
# Loss (chunked, vocab-sharded logits) and serving step.
# --------------------------------------------------------------------------

def chunked_xent(x, w_head, labels, mask, chunk: int = 512,
                 z_loss: float = 1e-4, unroll: bool = False):
    """Cross entropy against vocab-sharded logits, computed in sequence
    chunks so the full (B,S,V) logits tensor never exists.

    x: (B,S,d); w_head: (d, V_pad); labels: (B,S) int32; mask: (B,S)."""
    B, S, d = x.shape
    V = w_head.shape[1]
    chunk = min(chunk, S)
    n = S // chunk if S % chunk == 0 else 1
    if S % chunk != 0:
        chunk = S
    xs = x.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, zt = carry
        xc, lc, mc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, w_head,
                            preferred_element_type=jnp.float32)
        logits = lsc(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, V, dtype=logits.dtype)
        picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = (lse - picked) * mc
        zl = jnp.square(lse) * mc
        return (tot + nll.sum(), zt + zl.sum()), None

    (tot, zt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms), unroll=unroll)
    denom = jnp.maximum(mask.sum(), 1).astype(jnp.float32)
    return tot / denom + z_loss * zt / denom


def lm_loss(params, cfg: ModelConfig, batch: dict, *, remat: bool = True,
            use_pallas: bool = False, aux_weight: float = 1e-2):
    """Causal LM loss; returns (loss, metrics)."""
    x, _, aux = forward(params, cfg, batch, cache=None, remat=remat,
                        use_pallas=use_pallas)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    S_total = x.shape[1]
    n_front = S_total - S_tok  # vlm: prepended patch positions
    # predict token t+1 at position n_front + t
    hx = x[:, n_front:-1] if S_tok > 1 else x[:, n_front:]
    labels = tokens[:, 1:]
    mask = (labels >= 0).astype(jnp.float32)
    loss = chunked_xent(hx, params["lm_head"], jnp.maximum(labels, 0), mask,
                        unroll=cfg.scan_unroll)
    total = loss + aux_weight * aux["moe_aux"]
    return total, {"xent": loss, "moe_aux": aux["moe_aux"],
                   "overflow": aux["overflow"]}


def serve_step(params, cfg: ModelConfig, cache: Cache, tokens):
    """One decode step for the whole batch.  tokens: (B, 1) int32.
    Returns (next_token (B,), new_cache)."""
    x, new_cache, _ = forward(params, cfg, {"tokens": tokens}, cache=cache,
                              remat=False)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["lm_head"],
                        preferred_element_type=jnp.float32)
    logits = lsc(logits, "batch", None, "vocab")
    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    return nxt, new_cache


def prefill(params, cfg: ModelConfig, cache: Cache, batch: dict):
    """Fill the cache with a prompt; returns (last-position hidden, cache)."""
    x, new_cache, _ = forward(params, cfg, batch, cache=cache, remat=False)
    return x[:, -1], new_cache
