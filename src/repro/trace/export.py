"""Host-side consumers of a captured :class:`~repro.trace.buffer.TraceBuf`:
the modeled-cycle timeline mapping, Chrome/Perfetto trace JSON export, a
JSONL event stream, and the utilization / work-imbalance / queue-depth
summary the CLI prints.

Timeline mapping.  The engine prices every round on the perf model's
cycle clock (``Stats.cycles``); the recorder stores each traced round's
increment (``cyc``) *and* the post-round running total (``cyc_total``),
so round ``r`` occupies the interval ``[cyc_total - cyc, cyc_total]`` in
modeled cycles — exact even when ``trace_every > 1`` leaves gaps, and the
last slot's ``cyc_total`` is bitwise ``Stats.cycles`` when the ring did
not wrap.  The Perfetto export writes modeled cycles as the trace's
microsecond ticks (1 cycle == 1 us tick; at the default 1 GHz tile clock
a displayed "us" is a real microsecond of modeled machine time * 1e3).

Track schema (Chrome trace-event JSON, loadable at ui.perfetto.dev):

* pid 0 "engine"   — one "X" slice per traced round (dur = the round's
  modeled cycles) + counters: frontier, pending, src_budget, launches,
  hbm_windows.
* pid 1 "tiles"    — one thread per tile; per round one "X" busy slice
  (dur = that tile's compute cycles — the gap to the round envelope IS
  the idle time the utilization figure plots), the critical-path tile's
  slice tagged ``crit=1``.
* pid 2 "channels" — per-channel counters: msgs, spills, qdepth (+ the
  TSU's granted budget).
* pid 3 "fabric"   — per-link-class flit counters (local / ruche / wrap /
  port / die).

Everything here is numpy-only and runs on the host after the jitted run
returns; nothing feeds back into the engine.
"""
from __future__ import annotations

import json

import numpy as np

# Link-class display names, indexed by the CLASS_* constants.
LINK_CLASS_NAMES = ("local", "ruche", "wrap", "port", "die")

PHASE_NAMES = ("ramp", "steady", "drain")


def lane_trace(tbuf, lane: int):
    """Slice one lane out of a lane-led ``(B, ...)`` serving TraceBuf —
    the per-query trace is exactly a solo trace."""
    import jax
    return jax.tree.map(lambda x: x[lane], tbuf)


def trace_arrays(tbuf) -> dict:
    """De-ring a TraceBuf into round-ordered numpy arrays.

    Returns ``{field: (n, ...) array}`` over the valid slots (``round_id
    >= 0``), sorted by round index — the ring keeps the LAST ``R``
    recorded rounds, so sorting by ``round_id`` restores time order —
    plus ``n_recorded`` (slots present) and ``n_seen`` (rounds ever
    recorded; ``n_seen > n_recorded`` means the ring wrapped and the
    oldest rounds were overwritten).
    """
    rid = np.asarray(tbuf.round_id)
    if rid.ndim != 1:
        raise ValueError(
            f"trace_arrays wants a single trace (round_id shape "
            f"{rid.shape}); slice serving lanes with lane_trace() first")
    valid = rid >= 0
    order = np.argsort(rid[valid], kind="stable")
    out = {"n_recorded": int(valid.sum()), "n_seen": int(tbuf.cursor)}
    out["round_id"] = rid[valid][order]
    for f in tbuf._fields:
        if f in ("cursor", "round_id"):
            continue
        v = np.asarray(getattr(tbuf, f))
        out[f] = v[valid][order]
    return out


def utilization(tr: dict) -> np.ndarray:
    """Per-round mean tile utilization: the fraction of the round's
    critical-path envelope the average tile spent computing,
    ``busy.sum() / (T * cyc_round)`` (0 where the round cost nothing)."""
    busy = tr["tile_busy"].astype(np.float64)
    cyc = tr["cyc"].astype(np.float64)
    T = busy.shape[1]
    denom = np.where(cyc > 0, T * cyc, 1.0)
    return np.where(cyc > 0, busy.sum(axis=1) / denom, 0.0)


def work_cov(tr: dict) -> np.ndarray:
    """Per-round work-imbalance coefficient of variation across tiles:
    ``std(tile_busy) / mean(tile_busy)`` (0 where no tile worked)."""
    busy = tr["tile_busy"].astype(np.float64)
    mean = busy.mean(axis=1)
    std = busy.std(axis=1)
    return np.where(mean > 0, std / np.where(mean > 0, mean, 1.0), 0.0)


def trace_metrics(tbuf) -> dict:
    """The two additive figure columns: mean utilization and mean work
    CoV over the recorded rounds (``derived_metrics``/``stats_row`` merge
    these when a trace is present)."""
    tr = trace_arrays(tbuf)
    if tr["n_recorded"] == 0:
        return {"util_mean": 0.0, "work_cov": 0.0}
    return {"util_mean": round(float(utilization(tr).mean()), 4),
            "work_cov": round(float(work_cov(tr).mean()), 4)}


def _starts(tr: dict) -> np.ndarray:
    return tr["cyc_total"].astype(np.float64) - tr["cyc"].astype(np.float64)


def summarize(tbuf) -> dict:
    """Utilization, work-imbalance and queue-depth statistics, overall
    and per execution phase (the recorded rounds split into ramp / steady
    / drain thirds by round order — the time-resolved split the run-level
    ``Stats`` aggregates away)."""
    tr = trace_arrays(tbuf)
    n = tr["n_recorded"]
    out = {"rounds_recorded": n, "rounds_seen": tr["n_seen"],
           "ring_wrapped": tr["n_seen"] > n}
    if n == 0:
        return out
    util = utilization(tr)
    cov = work_cov(tr)
    qd = tr["qdepth"]
    out.update(
        cycles_traced=float(tr["cyc"].astype(np.float64).sum()),
        util_mean=float(util.mean()),
        util_min=float(util.min()), util_max=float(util.max()),
        work_cov=float(cov.mean()),
        crit_tile_mode=int(np.bincount(tr["crit_tile"]).argmax()),
    )
    K = qd.shape[1]
    out["channels"] = [
        {"chan": k,
         "msgs": int(tr["msgs"][:, k].sum()),
         "spills": int(tr["spills"][:, k].sum()),
         "q_p50": float(np.percentile(qd[:, k], 50)),
         "q_p90": float(np.percentile(qd[:, k], 90)),
         "q_max": int(qd[:, k].max()),
         "q_tile_max": int(tr["qdepth_max"][:, k].max())}
        for k in range(K)]
    bounds = [0, n // 3, (2 * n) // 3, n]
    phases = []
    for p, name in enumerate(PHASE_NAMES):
        lo, hi = bounds[p], bounds[p + 1]
        if hi <= lo:
            continue
        sl = slice(lo, hi)
        phases.append({
            "phase": name, "rounds": hi - lo,
            "util_mean": float(util[sl].mean()),
            "work_cov": float(cov[sl].mean()),
            "q_p50": float(np.percentile(qd[sl].sum(axis=1), 50)),
            "q_p90": float(np.percentile(qd[sl].sum(axis=1), 90)),
            "q_max": int(qd[sl].sum(axis=1).max()),
            "spills": int(tr["spills"][sl].sum()),
        })
    out["phases"] = phases
    return out


def format_summary(s: dict) -> str:
    """The CLI table for a :func:`summarize` dict."""
    lines = [f"rounds recorded {s['rounds_recorded']} "
             f"(seen {s['rounds_seen']}"
             + (", ring wrapped)" if s.get("ring_wrapped") else ")")]
    if s["rounds_recorded"] == 0:
        return "\n".join(lines)
    lines.append(
        f"util mean {s['util_mean']:.3f} "
        f"[{s['util_min']:.3f}..{s['util_max']:.3f}]  "
        f"work CoV {s['work_cov']:.3f}  "
        f"critical-path tile (mode) {s['crit_tile_mode']}")
    lines.append(f"{'phase':8s} {'rounds':>7s} {'util':>6s} {'cov':>6s} "
                 f"{'q_p50':>7s} {'q_p90':>7s} {'q_max':>7s} {'spills':>7s}")
    for p in s["phases"]:
        lines.append(f"{p['phase']:8s} {p['rounds']:7d} "
                     f"{p['util_mean']:6.3f} {p['work_cov']:6.3f} "
                     f"{p['q_p50']:7.0f} {p['q_p90']:7.0f} "
                     f"{p['q_max']:7d} {p['spills']:7d}")
    lines.append(f"{'chan':8s} {'msgs':>9s} {'spills':>7s} {'q_p50':>7s} "
                 f"{'q_p90':>7s} {'q_max':>7s}")
    for c in s["channels"]:
        lines.append(f"{c['chan']:<8d} {c['msgs']:9d} {c['spills']:7d} "
                     f"{c['q_p50']:7.0f} {c['q_p90']:7.0f} {c['q_max']:7d}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# Chrome/Perfetto trace JSON.
# --------------------------------------------------------------------------

PID_ENGINE, PID_TILES, PID_CHANNELS, PID_FABRIC = 0, 1, 2, 3


def to_perfetto(tbuf, meta: dict | None = None) -> dict:
    """Build the Chrome trace-event JSON dict (see module docstring for
    the track schema).  ``meta`` lands in ``otherData``."""
    tr = trace_arrays(tbuf)
    ev = []

    def m(pid, name, tid=None):
        e = {"ph": "M", "pid": pid, "name": "process_name",
             "args": {"name": name}}
        if tid is not None:
            e["name"] = "thread_name"
            e["tid"] = tid
        ev.append(e)

    m(PID_ENGINE, "engine")
    m(PID_TILES, "tiles")
    m(PID_CHANNELS, "channels")
    m(PID_FABRIC, "fabric")
    n = tr["n_recorded"]
    T = tr["tile_busy"].shape[1] if n else 0
    for t in range(T):
        m(PID_TILES, f"tile {t}", tid=t)
    starts = _starts(tr)
    for r in range(n):
        rid = int(tr["round_id"][r])
        ts = float(starts[r])
        dur = float(tr["cyc"][r])
        ev.append({"ph": "X", "pid": PID_ENGINE, "tid": 0, "ts": ts,
                   "dur": dur, "name": f"round {rid}",
                   "args": {"round": rid,
                            "pending": int(tr["pending"][r]),
                            "frontier": int(tr["frontier"][r])}})
        for name, key in (("frontier", "frontier"), ("pending", "pending"),
                          ("src_budget", "src_budget"),
                          ("launches", "launches"),
                          ("hbm_windows", "hbm_windows")):
            ev.append({"ph": "C", "pid": PID_ENGINE, "tid": 0, "ts": ts,
                       "name": name, "args": {name: int(tr[key][r])}})
        crit = int(tr["crit_tile"][r])
        for t in range(T):
            busy = float(tr["tile_busy"][r, t])
            args = {"round": rid}
            if t == crit:
                args["crit"] = 1
            ev.append({"ph": "X", "pid": PID_TILES, "tid": t, "ts": ts,
                       "dur": busy, "name": "busy", "args": args})
        for k in range(tr["msgs"].shape[1]):
            ev.append({"ph": "C", "pid": PID_CHANNELS, "tid": k, "ts": ts,
                       "name": f"chan{k}",
                       "args": {"msgs": int(tr["msgs"][r, k]),
                                "spills": int(tr["spills"][r, k]),
                                "qdepth": int(tr["qdepth"][r, k]),
                                "budget": int(tr["chan_budget"][r, k])}})
        for c, cname in enumerate(LINK_CLASS_NAMES):
            flits = int(tr["link_cls"][r, c])
            if tr["link_cls"][:, c].any():
                ev.append({"ph": "C", "pid": PID_FABRIC, "tid": c, "ts": ts,
                           "name": f"flits_{cname}",
                           "args": {"flits": flits}})
    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"clock": "modeled cycles (1 cycle = 1 us tick)",
                          **(meta or {})}}


def write_perfetto(tbuf, path: str, meta: dict | None = None) -> dict:
    doc = to_perfetto(tbuf, meta)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def jsonl_rows(tbuf) -> list:
    """One JSON-able event dict per recorded round (the stream form of
    the same data the Perfetto export plots)."""
    tr = trace_arrays(tbuf)
    starts = _starts(tr)
    util = utilization(tr) if tr["n_recorded"] else np.zeros(0)
    cov = work_cov(tr) if tr["n_recorded"] else np.zeros(0)
    rows = []
    for r in range(tr["n_recorded"]):
        rows.append({
            "round": int(tr["round_id"][r]),
            "cycle_start": float(starts[r]),
            "cycles": float(tr["cyc"][r]),
            "cycle_total": float(tr["cyc_total"][r]),
            "util": round(float(util[r]), 6),
            "work_cov": round(float(cov[r]), 6),
            "crit_tile": int(tr["crit_tile"][r]),
            "tile_busy": [round(float(x), 2) for x in tr["tile_busy"][r]],
            "msgs": tr["msgs"][r].tolist(),
            "spills": tr["spills"][r].tolist(),
            "qdepth": tr["qdepth"][r].tolist(),
            "qdepth_max": tr["qdepth_max"][r].tolist(),
            "chan_budget": tr["chan_budget"][r].tolist(),
            "src_budget": int(tr["src_budget"][r]),
            "link_cls": {n: int(tr["link_cls"][r, c])
                         for c, n in enumerate(LINK_CLASS_NAMES)
                         if tr["link_cls"][:, c].any()},
            "launches": int(tr["launches"][r]),
            "hbm_windows": int(tr["hbm_windows"][r]),
            "frontier": int(tr["frontier"][r]),
            "pending": int(tr["pending"][r]),
        })
    return rows


def write_jsonl(tbuf, path: str) -> int:
    rows = jsonl_rows(tbuf)
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    return len(rows)


def reconcile_cycles(tbuf, stats_cycles: float) -> dict:
    """Check the trace's cycle totals against the accumulated
    ``Stats.cycles``: when the ring did not wrap and every round was
    traced (``trace_every == 1``), the last slot's running total is the
    SAME f32 the engine accumulated (bitwise), and the per-round
    increments sum to it up to f32 rounding.  Returns the comparison."""
    tr = trace_arrays(tbuf)
    if tr["n_recorded"] == 0:
        return {"exact": False, "n": 0}
    last_total = float(tr["cyc_total"][-1])
    inc_sum = float(tr["cyc"].astype(np.float64).sum())
    exact = (not tr["n_seen"] > tr["n_recorded"]) and \
        last_total == float(stats_cycles)
    rel = abs(inc_sum - float(stats_cycles)) / max(float(stats_cycles), 1.0)
    return {"exact": bool(exact), "n": tr["n_recorded"],
            "last_total": last_total, "increment_sum": inc_sum,
            "stats_cycles": float(stats_cycles), "increment_rel_err": rel}
