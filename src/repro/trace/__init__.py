"""Flight recorder: opt-in per-round trace capture for the Dalorex engine.

``EngineConfig(trace=True, trace_every=k, trace_rounds=R)`` makes the
engine carry a :class:`TraceBuf` ring through the round loop, recording
per-channel msgs/spills/queue depth, per-tile busy cycles and the round's
critical-path tile, per-link-class flits, TSU budget decisions, HBM DMA
windows and frontier/pending population — every round (or every k-th),
bounded by the R-slot ring.  Trace-off is byte-identical to a build
without the recorder; trace-on never perturbs values or ``Stats``.

Consumers (:mod:`repro.trace.export`): Chrome/Perfetto trace JSON on the
modeled-cycle timeline, a JSONL event stream, and the utilization /
work-imbalance / queue-depth summary.  CLI::

    PYTHONPATH=src python -m repro.trace summarize [--preset rmat-small]
    PYTHONPATH=src python -m repro.trace export --out run.perfetto.json

See DESIGN.md "Tracing & observability".
"""
from repro.trace.buffer import (SERIES_FIELDS, TraceBuf, record_round,
                                zero_trace)
from repro.trace.export import (LINK_CLASS_NAMES, format_summary,
                                jsonl_rows, lane_trace, reconcile_cycles,
                                summarize, to_perfetto, trace_arrays,
                                trace_metrics, utilization, work_cov,
                                write_jsonl, write_perfetto)

__all__ = [
    "TraceBuf", "SERIES_FIELDS", "record_round", "zero_trace",
    "LINK_CLASS_NAMES", "format_summary", "jsonl_rows", "lane_trace",
    "reconcile_cycles", "summarize", "to_perfetto", "trace_arrays",
    "trace_metrics", "utilization", "work_cov", "write_jsonl",
    "write_perfetto",
]
