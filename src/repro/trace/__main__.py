"""Flight-recorder CLI: run a preset workload with the trace on, then
summarize it or export it for ui.perfetto.dev.

  PYTHONPATH=src python -m repro.trace summarize [--preset rmat-small]
      [--app bfs] [--scale N --tiles T] [--noc mesh] [--placement ...]
      [--trace-every k --trace-rounds R]
  PYTHONPATH=src python -m repro.trace export --out run.perfetto.json
      [--jsonl run.jsonl] [same run flags]

``summarize`` prints the utilization / work-imbalance / queue-depth table
(overall, per phase, per channel).  ``export`` writes the Chrome/Perfetto
trace JSON (and optionally the JSONL round stream) and reconciles the
trace's cycle timeline against the run's ``Stats.cycles`` — exact (bitwise)
whenever the ring held every round.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="capture + inspect a flight-recorder trace")
    ap.add_argument("cmd", choices=("summarize", "export"))
    ap.add_argument("--preset", default="rmat-small",
                    help="repro.configs.dalorex_graph preset naming the "
                         "graph/tiles/noc shape (flags below override)")
    ap.add_argument("--app", default="bfs",
                    choices=("bfs", "sssp", "wcc", "pagerank", "spmv"))
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--tiles", type=int, default=None)
    ap.add_argument("--backend", choices=("xla", "pallas"), default=None)
    ap.add_argument("--noc", default=None,
                    choices=("ideal", "mesh", "torus", "ruche", "hier"))
    ap.add_argument("--ndies-y", type=int, default=None)
    ap.add_argument("--ndies-x", type=int, default=None)
    ap.add_argument("--placement", default=None,
                    choices=("low_order", "high_order",
                             "low_order_dielocal", "high_order_dielocal"))
    ap.add_argument("--mode", choices=("async", "bsp"), default="async")
    ap.add_argument("--trace-every", type=int, default=1)
    ap.add_argument("--trace-rounds", type=int, default=4096)
    ap.add_argument("--out", default=None,
                    help="export: Perfetto JSON path "
                         "(default <app>.perfetto.json)")
    ap.add_argument("--jsonl", default=None,
                    help="export: also write the per-round JSONL stream")
    return ap


def traced_run(args):
    """One traced engine run per the CLI flags; returns
    ``(result, cfg, meta)`` where ``result.trace`` is the TraceBuf."""
    from repro.configs.dalorex_graph import PRESETS
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    wl = PRESETS[args.preset]
    scale = args.scale if args.scale is not None else wl.scale
    tiles = args.tiles if args.tiles is not None else wl.tiles
    backend = args.backend if args.backend is not None else wl.backend
    noc = args.noc if args.noc is not None else wl.noc
    ndies = (args.ndies_y if args.ndies_y is not None else wl.ndies[0],
             args.ndies_x if args.ndies_x is not None else wl.ndies[1])
    placement = args.placement if args.placement is not None \
        else wl.placement
    dies = ndies if placement.endswith("_dielocal") else None

    cfg = EngineConfig(mode=args.mode, backend=backend, noc=noc,
                       ndies_y=ndies[0], ndies_x=ndies[1],
                       edge_space=wl.edge_space, hbm_window=wl.hbm_window,
                       trace=True, trace_every=args.trace_every,
                       trace_rounds=args.trace_rounds)
    n, src, dst, val = rmat_edges(scale, edge_factor=wl.edge_factor, seed=1)
    g = CSRGraph.from_edges(n, src, dst, val)
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    meta = {"app": args.app, "preset": args.preset, "scale": scale,
            "tiles": tiles, "backend": backend, "noc": noc,
            "placement": placement, "mode": args.mode,
            "trace_every": args.trace_every, "V": g.num_vertices,
            "E": g.num_edges, "root": root}
    if args.app == "wcc":
        gs = alg.symmetrize(g)
        pg = alg.prepare(gs, tiles, scheme=placement, dies=dies)
        res = alg.wcc(pg, cfg)
    else:
        pg = alg.prepare(g, tiles, scheme=placement, dies=dies)
        if args.app == "bfs":
            res = alg.bfs(pg, root, cfg)
        elif args.app == "sssp":
            res = alg.sssp(pg, root, cfg)
        elif args.app == "pagerank":
            res = alg.pagerank(pg, iters=4, cfg=cfg)
            meta["note"] = "trace covers the LAST PageRank epoch"
        else:
            x = np.random.default_rng(0).normal(
                size=g.num_vertices).astype(np.float32)
            res = alg.spmv(pg, x, cfg)
    return res, cfg, meta


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    from repro.trace.export import (format_summary, reconcile_cycles,
                                    summarize, write_jsonl, write_perfetto)

    res, cfg, meta = traced_run(args)
    line = " ".join(f"{k}={v}" for k, v in meta.items())
    print(line)
    print(f"rounds={int(np.asarray(res.stats.rounds))} "
          f"cycles={float(np.asarray(res.stats.cycles)):.0f} "
          f"energy_pj={float(np.asarray(res.stats.energy_pj)):.0f}")

    if args.cmd == "summarize":
        print(format_summary(summarize(res.trace)))
        rec = reconcile_cycles(res.trace,
                               float(np.asarray(res.stats.cycles)))
        print(f"cycle reconcile: exact={rec['exact']} "
              f"last_total={rec['last_total']:.0f} "
              f"stats={rec['stats_cycles']:.0f}")
        return 0

    out = args.out or f"{args.app}.perfetto.json"
    doc = write_perfetto(res.trace, out, meta=meta)
    print(f"wrote {out}: {len(doc['traceEvents'])} events")
    if args.jsonl:
        n = write_jsonl(res.trace, args.jsonl)
        print(f"wrote {args.jsonl}: {n} rounds")
    rec = reconcile_cycles(res.trace, float(np.asarray(res.stats.cycles)))
    print(f"cycle reconcile: exact={rec['exact']} "
          f"n={rec['n']} last_total={rec['last_total']:.0f} "
          f"stats={rec['stats_cycles']:.0f} "
          f"inc_rel_err={rec['increment_rel_err']:.2e}")
    # the acceptance contract: a full (unwrapped, every-round) trace's
    # timeline must land bitwise on the accumulated Stats.cycles
    if args.trace_every == 1 and not rec["exact"]:
        print("ERROR: trace timeline does not reconcile with Stats.cycles",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
