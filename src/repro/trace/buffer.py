"""The flight recorder's in-loop ring buffer.

:class:`TraceBuf` is a fixed-shape pytree of per-round series carried
through the engine's ``lax.while_loop`` (and the serving lane vmap) when
``EngineConfig.trace=True``.  Every leaf is a ``(R, ...)`` ring of
``R = EngineConfig.trace_rounds`` slots; one slot is written every
``EngineConfig.trace_every``-th round by :func:`record_round` (a masked
``dynamic_update_index_in_dim`` — shape-safe inside scan/while/vmap, the
same discipline as ``zero_stats``/``_acc_stats``).  When the traversal
outlives the ring, the oldest slots are overwritten: the buffer always
holds the LAST ``R`` recorded rounds, identifiable by their ``round_id``.

The recording contract (tests/test_trace.py):

* trace-off (``cfg.trace=False``) is byte-identical to a build without the
  recorder — the carry slot is an empty pytree, no ops are added;
* trace-on never perturbs values or ``Stats``: every recorded quantity is
  a *read* of telemetry the round already computed (or an extra pure
  reduction over it), on both execution backends and both comm backends.

All recorded values are *global* (post ``psum``/``pmax``/``all_gather``),
so under shard_map every device carries an identical replicated TraceBuf
(``out_specs=P()``), exactly like ``Stats``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.noc.topology import N_LINK_CLASSES


class TraceBuf(NamedTuple):
    """Per-round series, each a ring of ``R`` slots (leading axis).

    ``cursor`` counts rounds *recorded* (monotonic — ``min(cursor, R)``
    slots are valid; ``cursor > R`` means the ring wrapped).  ``round_id``
    holds each slot's engine round index (-1 = never written), so the
    host can re-order the ring and map slots onto the modeled-cycle
    timeline via ``cyc_total`` (the post-round ``Stats.cycles`` value:
    the round occupies ``[cyc_total - cyc, cyc_total]``).
    """

    cursor: jax.Array       # () i32 — rounds recorded so far
    round_id: jax.Array     # (R,) i32 — engine round index per slot (-1)
    cyc: jax.Array          # (R,) f32 — modeled cycles of the round
    cyc_total: jax.Array    # (R,) f32 — Stats.cycles after the round
    tile_busy: jax.Array    # (R, T) f32 — per-tile compute cycles
    crit_tile: jax.Array    # (R,) i32 — the round's critical-path tile
    msgs: jax.Array         # (R, K) i32 — delivered messages per channel
    spills: jax.Array       # (R, K) i32 — spill-and-replay per channel
    qdepth: jax.Array       # (R, K) i32 — total queue occupancy per chan
    qdepth_max: jax.Array   # (R, K) i32 — max single-tile occupancy
    chan_budget: jax.Array  # (R, K) i32 — TSU pop budgets granted (sum
                            # over tiles; the arbiter's decisions)
    src_budget: jax.Array   # (R,) i32 — frontier-source budget granted
    link_cls: jax.Array     # (R, C) i32 — flits per link class
    launches: jax.Array     # (R,) i32 — pallas_call dispatches this round
    hbm_windows: jax.Array  # (R,) i32 — DMA windows fetched this round
    frontier: jax.Array     # (R,) i32 — global frontier population
    pending: jax.Array      # (R,) i32 — global pending work after round


# Fields written by record_round (everything except the bookkeeping pair).
SERIES_FIELDS = tuple(f for f in TraceBuf._fields
                      if f not in ("cursor", "round_id"))


def zero_trace(cfg, T: int, alg=None) -> TraceBuf:
    """A fresh ring sized for ``cfg`` (R, trace cadence), a ``T``-tile
    grid and the program's channel count — the TraceBuf analogue of
    ``zero_stats``.  ``alg`` is an AlgSpec or Program (defaults to the
    classic 3-task shape's 2 channels)."""
    from repro.core.program import as_program
    R = int(cfg.trace_rounds)
    assert R >= 1, f"trace_rounds={R} must be >= 1"
    assert int(cfg.trace_every) >= 1, \
        f"trace_every={cfg.trace_every} must be >= 1"
    K = len(as_program(alg).channels) if alg is not None else 2
    C = N_LINK_CLASSES
    zi = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zf = lambda *s: jnp.zeros(s, jnp.float32)  # noqa: E731
    return TraceBuf(
        cursor=zi(),
        round_id=jnp.full((R,), -1, jnp.int32),
        cyc=zf(R), cyc_total=zf(R),
        tile_busy=zf(R, T), crit_tile=zi(R),
        msgs=zi(R, K), spills=zi(R, K),
        qdepth=zi(R, K), qdepth_max=zi(R, K),
        chan_budget=zi(R, K), src_budget=zi(R),
        link_cls=zi(R, C), launches=zi(R),
        hbm_windows=zi(R), frontier=zi(R), pending=zi(R),
    )


def record_round(tbuf: TraceBuf, row: dict, round_ix, every: int
                 ) -> TraceBuf:
    """Write one round's series values into the ring (masked, in-loop).

    ``row`` maps :data:`SERIES_FIELDS` names to this round's values (each
    shaped like one slot of the field).  The slot is written — and the
    cursor advanced — only when ``round_ix % every == 0``; otherwise every
    buffer passes through untouched (a no-op ``where`` on one slot), so
    the carry shape stays fixed for ``lax.while_loop``.
    """
    R = tbuf.round_id.shape[0]
    do = (round_ix % jnp.int32(every)) == 0
    slot = jnp.remainder(tbuf.cursor, jnp.int32(R))

    def wr(buf, v):
        v = jnp.asarray(v).astype(buf.dtype)
        old = jax.lax.dynamic_index_in_dim(buf, slot, axis=0,
                                           keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            buf, jnp.where(do, v, old), slot, axis=0)

    assert set(row) == set(SERIES_FIELDS), (
        f"record_round row keys {sorted(row)} != {sorted(SERIES_FIELDS)}")
    out = {name: wr(getattr(tbuf, name), v) for name, v in row.items()}
    out["round_id"] = wr(tbuf.round_id, jnp.asarray(round_ix, jnp.int32))
    return tbuf._replace(cursor=tbuf.cursor + do.astype(jnp.int32), **out)
