"""Seekable synthetic data pipelines.

Fault tolerance demands that ``batch(step)`` is a pure function of
``(seed, step)`` — after a crash/restore the stream resumes bit-identically
with no replay divergence.  Two LM sources:

* ``UniformSynthetic`` — iid tokens (shape/throughput testing).
* ``MarkovSynthetic`` — a fixed random bigram chain; a real model visibly
  learns it, so convergence tests have signal.

Graph datasets (RMAT, per the paper's evaluation) live in core/graph.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class UniformSynthetic:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return rng.integers(0, self.vocab, (self.batch, self.seq_len),
                            dtype=np.int32)


@dataclasses.dataclass
class MarkovSynthetic:
    """Tokens follow a sparse random bigram table (8 likely successors per
    token) — cross-entropy floor ~log(8) instead of log(V)."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.successors = rng.integers(
            0, self.vocab, (self.vocab, self.branching), dtype=np.int32)

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 1, step))
        out = np.empty((self.batch, self.seq_len), np.int32)
        cur = rng.integers(0, self.vocab, self.batch, dtype=np.int32)
        out[:, 0] = cur
        choices = rng.integers(0, self.branching,
                               (self.batch, self.seq_len), dtype=np.int32)
        for t in range(1, self.seq_len):
            cur = self.successors[cur, choices[:, t]]
            out[:, t] = cur
        return out


def make_source(kind: str, vocab: int, seq_len: int, batch: int,
                seed: int = 0):
    if kind == "uniform":
        return UniformSynthetic(vocab, seq_len, batch, seed)
    if kind == "markov":
        return MarkovSynthetic(vocab, seq_len, batch, seed)
    raise ValueError(kind)
