"""Production training loop: auto-resume, atomic checkpoints, straggler
monitoring, optional gradient accumulation + compressed DP all-reduce.

Fault-tolerance model (maps to a real pod deployment):
  * crash/preemption -> restart re-enters `train()`; `latest_valid_step`
    finds the newest intact checkpoint; the seekable data pipeline resumes
    bit-identically at that step (tested by killing mid-run in
    tests/test_runtime.py);
  * elastic re-scale  -> checkpoints are logical arrays; restore re-shards
    onto whatever mesh the restarted job has;
  * stragglers        -> per-step wall time feeds an EWMA; steps slower than
    ``straggler_factor``x the EWMA are flagged (on a real pod the flag
    triggers the re-mesh/elastic path; here it is logged + counted).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import make_source
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel.collectives import compress_tree
from repro.parallel.sharding import (current_mesh, current_rules,
                                     tree_shardings)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 64
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    data: str = "markov"
    seed: int = 0
    microbatches: int = 1        # gradient accumulation
    remat: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    opt: adamw.OptConfig = adamw.OptConfig()


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Builds the jitted (params, opt_state, batch) -> ... step function."""
    oc = tc.opt

    def loss_fn(params, batch):
        loss, metrics = tfm.lm_loss(params, cfg, batch, remat=tc.remat)
        return loss, metrics

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        if tc.microbatches > 1:
            B = tokens.shape[0]
            mb = B // tc.microbatches
            mbatches = {k: v.reshape((tc.microbatches, mb) + v.shape[1:])
                        for k, v in batch.items()}

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros(())), mbatches)
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
            loss = lsum / tc.microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        # force grads onto the parameter shardings (reduce-scatter over the
        # fsdp axis instead of a full all-reduce — §Perf train iteration A)
        mesh, rules = current_mesh(), current_rules()
        if mesh is not None and rules is not None:
            shardings = tree_shardings(tfm.abstract_params(cfg), mesh, rules)
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, shardings)

        if oc.compress_grads:
            grads, ef = compress_tree(grads, opt_state.ef, axis=None)
            opt_state = opt_state._replace(ef=ef)

        params, opt_state, om = adamw.apply_updates(params, opt_state,
                                                    grads, oc)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.2
    ewma: float | None = None
    flags: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.factor * self.ewma
        if slow:
            self.flags += 1
        else:  # stragglers do not poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def train(cfg: ModelConfig, tc: TrainConfig, mesh=None, stop_after=None):
    """Run (or resume) training.  Returns (params, opt_state, history)."""
    source = make_source(tc.data, cfg.vocab_size, tc.seq_len, tc.batch,
                         tc.seed)
    start = store.latest_valid_step(tc.ckpt_dir)
    if start is None:
        params = tfm.init_params(jax.random.PRNGKey(tc.seed), cfg)
        opt_state = adamw.init(params, tc.opt)
        start = 0
    else:
        template = jax.eval_shape(lambda: (lambda p: {
            "params": p, "opt": adamw.init(p, tc.opt)})(
                tfm.init_params(jax.random.PRNGKey(tc.seed), cfg)))
        restored = store.restore(tc.ckpt_dir, start, template)
        params = jax.tree.map(jnp.asarray, restored["params"])
        opt_state = jax.tree.map(jnp.asarray, restored["opt"])

    # donate buffers only when params are stored in a reduced dtype (bf16
    # production path); fp32 params alias the fp32 master after one step.
    donate = (0, 1) if cfg.dtype != "float32" else ()
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=donate)
    monitor = StragglerMonitor(tc.straggler_factor)
    history = []

    for step in range(start, tc.steps):
        batch = {"tokens": jnp.asarray(source.batch_at(step))}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)
        history.append({"step": step, "loss": loss, "dt": dt,
                        "straggler": slow})
        if tc.log_every and step % tc.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"dt {dt*1e3:.0f}ms{'  [STRAGGLER]' if slow else ''}")
        done = step + 1
        if done % tc.ckpt_every == 0 or done == tc.steps:
            store.save(tc.ckpt_dir, done,
                       {"params": params, "opt": opt_state},
                       extra={"arch": cfg.name}, keep=tc.ckpt_keep)
        if stop_after is not None and done - start >= stop_after:
            break
    return params, opt_state, history
