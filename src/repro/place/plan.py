"""Telemetry-driven migration planning (the trace -> placement half).

The planner turns observed telemetry — per-tile busy-cycle series from the
flight recorder (:mod:`repro.trace`), or static structure when no trace is
available — into a :class:`MigrationPlan`: a set of disjoint placed-slot
*swap pairs*.  Swaps (rather than one-way moves) keep the owner map a
permutation by construction, which is what makes applying a plan a pure
relabeling (see :mod:`repro.place.migrate`).

Two scoring phases, mirroring the two imbalances the paper's §5 placement
study separates:

* **Die affinity** (cross-die traffic): every placed vertex gets a per-die
  edge-endpoint histogram; a vertex whose edges mostly touch another die is
  a candidate to move there.  Candidates prefer free padding slots on the
  target die (one vertex moves), else they pair with a mutually-wanting
  candidate (both move).  Each applied pair strictly removes cross-die edge
  endpoints, which is what drives the DIE-class flit reduction fig15 gates
  on.
* **Work balance** (intra-die, busy-cycle share): with die-aligned edge
  chunks every tile scans the same number of edges per round, so the
  residual imbalance is update-fold work — in-degree mass.  The planner
  swaps high-in-degree vertices of the hottest tile (by observed busy
  cycles, falling back to in-degree mass when no trace is given) against
  low-in-degree slots of the coldest same-die tile.  Restricting phase B
  to intra-die pairs means it can never undo phase A's DIE-flit win.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import PartitionedGraph


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Disjoint placed-slot swap pairs: slot ``pairs[i, 0]`` exchanges its
    vertex (or padding hole) with slot ``pairs[i, 1]``.  ``reason`` tags
    each pair ``'die'`` (phase A) or ``'bal'`` (phase B) for reporting."""

    pairs: np.ndarray           # (M, 2) int64 placed-slot ids
    reason: tuple[str, ...] = ()

    @property
    def num_pairs(self) -> int:
        return len(self.pairs)

    def moved_vertices(self, pg: PartitionedGraph) -> int:
        """Real (non-padding) vertices that change owner under this plan."""
        if not len(self.pairs):
            return 0
        return int((pg.inv[self.pairs.reshape(-1)] >= 0).sum())


def empty_plan() -> MigrationPlan:
    return MigrationPlan(pairs=np.zeros((0, 2), np.int64))


def validate_plan(pg: PartitionedGraph, plan: MigrationPlan) -> None:
    """Raise if ``plan`` is not a set of disjoint in-range swap pairs."""
    p = np.asarray(plan.pairs, np.int64)
    if p.size == 0:
        return
    if p.ndim != 2 or p.shape[1] != 2:
        raise ValueError(f"pairs must be (M, 2); got {p.shape}")
    flat = p.reshape(-1)
    if flat.min() < 0 or flat.max() >= len(pg.inv):
        raise ValueError("pair slot out of placed range")
    if np.any(p[:, 0] == p[:, 1]):
        raise ValueError("self-swap pair")
    if len(np.unique(flat)) != len(flat):
        raise ValueError("pairs must be disjoint (each slot in <= 1 pair)")


def placed_edges(pg: PartitionedGraph) -> tuple[np.ndarray, np.ndarray]:
    """Every real edge as ``(src_placed, dst_placed)`` int64 arrays.

    Works in all three edge modes because ``ptr_start`` is a *global*
    placed-edge index into the flattened ``(T * e_chunk,)`` shard in each
    of them.
    """
    deg = np.asarray(pg.deg, np.int64).reshape(-1)
    ptr = np.asarray(pg.ptr_start, np.int64).reshape(-1)
    dst_flat = np.asarray(pg.edge_dst, np.int64).reshape(-1)
    src = np.repeat(np.arange(len(deg), dtype=np.int64), deg)
    within = np.arange(int(deg.sum()), dtype=np.int64) \
        - np.repeat(np.cumsum(deg) - deg, deg)
    return src, dst_flat[np.repeat(ptr, deg) + within]


def score_tiles(trace) -> np.ndarray:
    """(T,) float64 observed busy cycles per tile, summed over the valid
    slots of the flight recorder's ring (the planner's work signal)."""
    from repro.trace.export import trace_arrays
    arr = trace_arrays(trace)
    return np.asarray(arr["tile_busy"], np.float64).sum(axis=0)


def indegree_mass(pg: PartitionedGraph) -> np.ndarray:
    """(v_pad,) int64 in-edge count per placed slot — the static stand-in
    for observed fold work when no trace is available (serving, round 0)."""
    _, dst = placed_edges(pg)
    return np.bincount(dst, minlength=len(pg.inv)).astype(np.int64)


def vertex_die_affinity(pg: PartitionedGraph,
                        tile_die: np.ndarray) -> np.ndarray:
    """(v_pad, n_dies) int64: edge endpoints joining each placed slot to
    vertices owned by each die (both directions counted)."""
    src, dst = placed_edges(pg)
    td = np.asarray(tile_die, np.int64)
    n_dies = int(td.max()) + 1
    die_of = td[np.arange(len(pg.inv)) // pg.v_chunk]
    aff = np.zeros((len(pg.inv), n_dies), np.int64)
    np.add.at(aff, (src, die_of[dst]), 1)
    np.add.at(aff, (dst, die_of[src]), 1)
    return aff


def _die_pairs(pg: PartitionedGraph, tile_die: np.ndarray,
               budget: int) -> tuple[list[tuple[int, int]], int]:
    """Phase A: cross-die-affinity swaps.  Returns (pairs, vertices_moved)."""
    v_chunk = pg.v_chunk
    td = np.asarray(tile_die, np.int64)
    if budget <= 0 or (td == td[0]).all():
        return [], 0
    aff = vertex_die_affinity(pg, td)
    die_of = td[np.arange(len(pg.inv)) // v_chunk]
    home_aff = aff[np.arange(len(aff)), die_of]
    # best foreign die per slot (mask the home column out of the argmax)
    masked = aff.copy()
    masked[np.arange(len(aff)), die_of] = -1
    want = masked.argmax(axis=1)
    gain = masked[np.arange(len(aff)), want] - home_aff
    real = pg.inv >= 0
    cand = np.nonzero(real & (gain > 0))[0]
    cand = cand[np.argsort(-gain[cand], kind="stable")]

    # padding slots per die, lowest-affinity-disturbance first (a pad slot
    # has no edges, so any one on the right die is as good as another)
    pad_by_die: dict[int, list[int]] = {}
    for s in np.nonzero(~real)[0]:
        pad_by_die.setdefault(int(die_of[s]), []).append(int(s))

    used = np.zeros(len(pg.inv), bool)
    unmatched: dict[tuple[int, int], list[int]] = {}  # (home, want) -> slots
    pairs: list[tuple[int, int]] = []
    moved = 0
    for v in cand:
        if moved >= budget:
            break
        v = int(v)
        if used[v]:
            continue
        h, w = int(die_of[v]), int(want[v])
        free = pad_by_die.get(w, [])
        while free and used[free[-1]]:
            free.pop()
        if free:
            p = free.pop()
            pairs.append((v, p))
            used[v] = used[p] = True
            moved += 1
            continue
        # mutual exchange: a waiting candidate on die w that wants die h
        queue = unmatched.get((w, h), [])
        while queue and used[queue[-1]]:
            queue.pop()
        if queue and moved + 2 <= budget:
            u = queue.pop()
            pairs.append((v, u))
            used[v] = used[u] = True
            moved += 2
        else:
            unmatched.setdefault((h, w), []).append(v)
    return pairs, moved


def _balance_pairs(pg: PartitionedGraph, busy: np.ndarray | None,
                   tile_die: np.ndarray | None, budget: int,
                   used: np.ndarray) -> tuple[list[tuple[int, int]], int]:
    """Phase B: intra-die hot/cold work-balance swaps."""
    if budget <= 0:
        return [], 0
    T, v_chunk = pg.T, pg.v_chunk
    mass = indegree_mass(pg)
    tile_mass = mass.reshape(T, v_chunk).sum(axis=1).astype(np.float64)
    tile_busy = (np.asarray(busy, np.float64)
                 if busy is not None else tile_mass)
    td = (np.asarray(tile_die, np.int64) if tile_die is not None
          else np.zeros(T, np.int64))
    real = pg.inv >= 0

    pairs: list[tuple[int, int]] = []
    moved = 0
    for die in np.unique(td):
        tiles = np.nonzero(td == die)[0]
        if len(tiles) < 2 or moved >= budget:
            continue
        hot = int(tiles[tile_busy[tiles].argmax()])
        cold = int(tiles[tile_busy[tiles].argmin()])
        if hot == cold or tile_busy[hot] <= tile_busy[cold]:
            continue
        # heaviest free vertices of the hot tile, lightest slots (padding
        # first: mass 0 and nothing to move back) of the cold tile
        h_slots = hot * v_chunk + np.arange(v_chunk)
        c_slots = cold * v_chunk + np.arange(v_chunk)
        h_free = h_slots[real[h_slots] & ~used[h_slots]]
        c_free = c_slots[~used[c_slots]]
        h_order = h_free[np.argsort(-mass[h_free], kind="stable")]
        c_order = c_free[np.argsort(mass[c_free]
                                    + np.where(real[c_free], 0, -1),
                                    kind="stable")]
        gap = tile_mass[hot] - tile_mass[cold]
        for hs, cs in zip(h_order, c_order):
            delta = float(mass[hs] - mass[cs])
            if delta <= 0 or 2 * delta >= gap:
                break  # stop before overshooting the other way
            cost = 1 + int(real[cs])
            if moved + cost > budget:
                break
            pairs.append((int(hs), int(cs)))
            used[hs] = used[cs] = True
            moved += cost
            gap -= 2 * delta
    return pairs, moved


def migration_plan(pg: PartitionedGraph, busy: np.ndarray | None = None,
                   *, budget: int = 64,
                   tile_die: np.ndarray | None = None) -> MigrationPlan:
    """Score tiles and emit a die-aware swap plan.

    ``busy`` — (T,) observed per-tile busy cycles (:func:`score_tiles` of a
    flight-recorder ring); ``None`` falls back to per-tile in-degree mass.
    ``budget`` caps the number of *real vertices* that change owner.
    Phase A (cross-die affinity) runs only when ``tile_die`` spans more
    than one die and gets first claim on the budget; phase B (intra-die
    balance) spends the remainder.
    """
    pairs_a, moved_a = ([], 0)
    if tile_die is not None:
        pairs_a, moved_a = _die_pairs(pg, tile_die, budget)
    used = np.zeros(len(pg.inv), bool)
    for a, b in pairs_a:
        used[a] = used[b] = True
    pairs_b, _ = _balance_pairs(pg, busy, tile_die, budget - moved_a, used)
    pairs = pairs_a + pairs_b
    if not pairs:
        return empty_plan()
    plan = MigrationPlan(
        pairs=np.asarray(pairs, np.int64),
        reason=tuple(["die"] * len(pairs_a) + ["bal"] * len(pairs_b)))
    validate_plan(pg, plan)
    return plan
