"""Closing the loop: telemetry in, adapted partition out.

Glue between the flight recorder (:mod:`repro.trace`), the planner
(:mod:`repro.place.plan`) and the relabeling machinery
(:mod:`repro.place.migrate`): :func:`adapt_partition` is the one call
sites use between epochs / queries, and :func:`adaptive_pagerank` is the
reference epoch-boundary driver — the same host loop as
:func:`repro.core.algorithms.pagerank`, but every ``cfg.adapt_every``
epochs it reads the last epoch's ring, migrates, remaps the rank vector
through original vertex ids, and prices the move into the accumulated
Stats.  Migration happens only at quiescent points (the engine is fully
drained between epochs), so no in-flight message ever sees a stale owner.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.engine import EngineConfig, PAGERANK, zero_stats
from repro.core.graph import CSRGraph, PartitionedGraph
from repro.place.migrate import apply_plan, price_migration
from repro.place.plan import MigrationPlan, empty_plan, migration_plan, \
    score_tiles


def cfg_tile_die(cfg: EngineConfig, T: int) -> np.ndarray | None:
    """The tile -> die map of ``cfg``'s fabric (None off the hier NoC)."""
    if cfg.noc != "hier" or cfg.ndies_x * cfg.ndies_y <= 1:
        return None
    from repro.noc.topology import tile_die_map
    return tile_die_map(T, cfg.noc_rows, cfg.ndies_y, cfg.ndies_x)


def plan_from_trace(pg: PartitionedGraph, cfg: EngineConfig,
                    trace) -> MigrationPlan:
    """Score the recorder's ring and plan within ``cfg.adapt_budget``."""
    busy = score_tiles(trace) if trace is not None else None
    return migration_plan(pg, busy, budget=cfg.adapt_budget,
                          tile_die=cfg_tile_die(cfg, pg.T))


def adapt_partition(g: CSRGraph, pg: PartitionedGraph, cfg: EngineConfig,
                    trace=None, busy=None
                    ) -> tuple[PartitionedGraph, MigrationPlan]:
    """One adaptation step: plan from telemetry, apply, return both.

    ``trace`` (a flight-recorder ring) wins over ``busy`` (a precomputed
    (T,) busy vector); with neither, the planner falls back to static
    in-degree mass.  Returns ``(pg, empty_plan())`` when the planner
    finds nothing to move — callers can cheaply call this every epoch.
    """
    if busy is None and trace is not None:
        busy = score_tiles(trace)
    tile_die = cfg_tile_die(cfg, pg.T)
    plan = migration_plan(pg, busy, budget=cfg.adapt_budget,
                          tile_die=tile_die)
    if not plan.num_pairs:
        return pg, empty_plan()
    return apply_plan(g, pg, plan, tile_die=tile_die), plan


def adaptive_pagerank(g: CSRGraph, pg: PartitionedGraph,
                      damping: float = 0.85, iters: int = 20,
                      cfg: EngineConfig = EngineConfig(), mesh=None,
                      params=None):
    """Epoch-synchronized PageRank with epoch-boundary migration.

    Requires ``cfg.trace`` when adapting from observed busy cycles;
    without a trace the planner's static fallback is used.  The relabeling
    contract makes each post-migration epoch bit-identical to the same
    epoch run on a partition *built* with the composed placement; against
    the unmigrated twin, values agree to float tolerance in general (the
    per-vertex acc fold order follows message arrival order, which is
    placement-dependent) and bitwise on instances whose epoch arithmetic
    is order-independent — integer-valued sums, or the dyadic pagerank
    instances ``tests/test_place.py`` constructs.

    Returns ``(result, pg_final, plans)``.
    """
    from repro.core.algorithms import (Result, _acc_stats, _call, real_mask,
                                       to_original)
    V = pg.num_vertices
    real = real_mask(pg)
    deg = np.asarray(pg.deg)
    rank = np.where(real, np.float32(1.0 / V), 0.0).astype(np.float32)
    total = zero_stats(cfg, pg.T, PAGERANK)
    plans: list[MigrationPlan] = []
    trace = None
    tile_die = cfg_tile_die(cfg, pg.T)
    for epoch in range(iters):
        if (cfg.adapt and epoch and epoch % max(cfg.adapt_every, 1) == 0):
            pg2, plan = adapt_partition(g, pg, cfg, trace=trace)
            if plan.num_pairs:
                from repro.place.migrate import remap_state
                rank = np.asarray(remap_state(pg, pg2, rank,
                                              fill=np.float32(0.0)))
                total = price_migration(total, pg, plan, pg.T,
                                        params=params, tile_die=tile_die)
                pg = pg2
                real = real_mask(pg)
                deg = np.asarray(pg.deg)
                plans.append(plan)
        frontier = jnp.asarray(real & (deg > 0))
        _, acc, stats, trace = _call(pg, PAGERANK, cfg, jnp.asarray(rank),
                                     frontier, mesh)
        acc = np.asarray(acc)
        dangling = rank[real & (deg == 0)].sum()
        rank = np.where(
            real, (1 - damping) / V + damping * (acc + dangling / V),
            0.0).astype(np.float32)
        total = _acc_stats(total, stats)
    res = Result(to_original(pg, rank).astype(np.float64), total, iters,
                 trace=trace)
    return res, pg, plans
