"""Applying a migration plan: relabel, re-deal, remap, price.

The load-bearing contract of :mod:`repro.place` lives here: applying a
plan is a *pure relabeling* of the owner map.  :func:`apply_plan` composes
the swap permutation with ``pg.place`` and rebuilds the shards through the
same :func:`repro.core.graph.build_partition` that built the original —
so the migrated partition is bitwise indistinguishable from one that
*started* with the composed placement, and converged values (mapped back
to original vertex ids) cannot depend on whether, or when, a migration
happened.  ``tests/test_place.py`` holds the engine to that.

Pricing follows the paper's cost discipline: nothing is free.  A migrated
vertex moves its state words (value, acc, frontier bit ~ 3 words) and its
edge segment (``deg`` words); cross-die moves additionally ride the
die-to-die serdes.  :func:`price_migration` folds the modeled cycles and
energy into ``Stats`` — including the leakage of the added cycles, so the
``energy_from_totals`` oracle still reconciles — and records the three
``migrated_vertices`` / ``migration_cycles`` / ``migration_pj`` counters
that fig15 reports.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import CSRGraph, PartitionedGraph, build_partition
from repro.place.plan import MigrationPlan, validate_plan


def swap_permutation(n_pad: int, pairs: np.ndarray) -> np.ndarray:
    """(n_pad,) int64 involution exchanging each pair's slots."""
    perm = np.arange(n_pad, dtype=np.int64)
    p = np.asarray(pairs, np.int64)
    if len(p):
        perm[p[:, 0]] = p[:, 1]
        perm[p[:, 1]] = p[:, 0]
    return perm


def apply_plan(g: CSRGraph, pg: PartitionedGraph, plan: MigrationPlan,
               tile_die: np.ndarray | None = None) -> PartitionedGraph:
    """Rebuild ``pg`` with ``plan``'s swaps composed into the owner map.

    Needs the host CSR ``g`` (the partition stores only placed shards) to
    re-deal the affected edge segments.  Preserves ``edge_mode`` and —
    via :func:`repro.core.algorithms.sort_adjacency` — the ``sorted_adj``
    layout triangle counting depends on.  Note ``e_chunk`` may change in
    the ``die_aligned`` / ``vertex_aligned`` modes (per-die / per-tile
    skew moved); callers re-validate queue sizing against the new shape.
    """
    validate_plan(pg, plan)
    perm = swap_permutation(len(pg.inv), plan.pairs)
    place_new = perm[pg.place]
    inv_new = np.empty_like(pg.inv)
    inv_new[perm] = pg.inv
    pg2 = build_partition(g, pg.T, place_new, inv_new, pg.edge_mode,
                          tile_die=tile_die)
    if pg.sorted_adj:
        from repro.core.algorithms import sort_adjacency
        pg2 = sort_adjacency(pg2)
    return pg2


# State words moved per vertex besides its edge segment: value, acc, and
# the packed frontier/metadata word.
STATE_WORDS = 3


def migration_words(pg: PartitionedGraph, plan: MigrationPlan,
                    tile_die: np.ndarray | None = None
                    ) -> tuple[int, int]:
    """64-bit words ``(intra_die, cross_die)`` the plan moves.

    Each *real* vertex in a pair moves ``STATE_WORDS + deg`` words (its
    state plus its out-edge segment); padding holes move nothing.  A word
    is cross-die when its pair's two slots live on different dies.
    """
    if not len(plan.pairs):
        return 0, 0
    deg = np.asarray(pg.deg, np.int64).reshape(-1)
    real = pg.inv >= 0
    td = (np.asarray(tile_die, np.int64) if tile_die is not None
          else np.zeros(pg.T, np.int64))
    die_of = td[np.asarray(plan.pairs, np.int64) // pg.v_chunk]  # (M, 2)
    cross = die_of[:, 0] != die_of[:, 1]
    slots = np.asarray(plan.pairs, np.int64)
    words = np.where(real[slots], STATE_WORDS + deg[slots], 0)  # (M, 2)
    per_pair = words.sum(axis=1)
    return (int(per_pair[~cross].sum()), int(per_pair[cross].sum()))


def price_migration(stats, pg: PartitionedGraph, plan: MigrationPlan,
                    T: int, params=None,
                    tile_die: np.ndarray | None = None):
    """Fold the plan's modeled migration cost into ``stats`` (host-side).

    Adds ``migration_cost`` cycles/energy plus the leakage of the added
    cycles (so ``energy_from_totals``, which derives leakage from total
    cycles, stays an exact oracle), and bumps the three migration
    counters.  Returns the updated Stats.
    """
    from repro.perf.model import PerfParams, leak_pj, migration_cost
    params = params or PerfParams()
    wi, wc = migration_words(pg, plan, tile_die)
    cyc, pj = migration_cost(params, wi, wc)
    leak = float(np.asarray(leak_pj(params, T, np.float32(cyc))))
    moved = plan.moved_vertices(pg)
    return stats._replace(
        cycles=stats.cycles + np.float32(cyc),
        energy_pj=stats.energy_pj + np.float32(pj + leak),
        migrated_vertices=stats.migrated_vertices + np.int32(moved),
        migration_cycles=stats.migration_cycles + np.float32(cyc),
        migration_pj=stats.migration_pj + np.float32(pj),
    )


def remap_state(pg_old: PartitionedGraph, pg_new: PartitionedGraph,
                arr, fill=0.0) -> np.ndarray:
    """Carry a ``(T, v_chunk)`` placed-space array across a migration.

    Routes through original vertex ids — ``out[slot owning v] = in[slot
    that owned v]`` — so it is exact for any pair of partitions of the
    same graph, not just swap-related ones.  Padding slots get ``fill``.
    """
    flat = np.asarray(arr).reshape(-1)
    ok_old = pg_old.inv >= 0
    orig = np.full(pg_old.num_vertices, fill, flat.dtype)
    orig[pg_old.inv[ok_old]] = flat[ok_old]
    ok_new = pg_new.inv >= 0
    out = np.full(len(pg_new.inv), fill, flat.dtype)
    out[ok_new] = orig[pg_new.inv[ok_new]]
    return out.reshape(pg_new.T, pg_new.v_chunk)
