"""Telemetry-driven adaptive placement (epoch-boundary migration).

Closes the trace -> placement loop the ROADMAP asks for: the flight
recorder (:mod:`repro.trace`) observes per-tile busy cycles and per-class
link traffic; the planner (:mod:`.plan`) turns them into a die-aware
vertex-swap plan; the migrator (:mod:`.migrate`) applies the plan as a
pure relabeling of the owner map (converged values bit-identical to the
unmigrated run — the contract ``tests/test_place.py`` enforces) and
prices the move into the perf model; :mod:`.adapt` glues the three into
epoch-boundary (``adaptive_pagerank``) and between-query
(:class:`repro.serve.frontend.Frontend`) call sites.
"""
from repro.place.adapt import (adapt_partition, adaptive_pagerank,
                               cfg_tile_die, plan_from_trace)
from repro.place.migrate import (apply_plan, migration_words, price_migration,
                                 remap_state, swap_permutation)
from repro.place.plan import (MigrationPlan, empty_plan, indegree_mass,
                              migration_plan, placed_edges, score_tiles,
                              validate_plan, vertex_die_affinity)

__all__ = [
    "MigrationPlan", "adapt_partition", "adaptive_pagerank", "apply_plan",
    "cfg_tile_die", "empty_plan", "indegree_mass", "migration_plan",
    "migration_words", "placed_edges", "plan_from_trace", "price_migration",
    "remap_state", "score_tiles", "swap_permutation", "validate_plan",
    "vertex_die_affinity",
]
