"""Atomic, sharded, elastic checkpointing.

Layout:  <dir>/step_<N>/   arrays.npz (flattened key -> full logical array)
                           manifest.json (step, keys, shapes, dtypes, sha256)

* atomic: written to ``step_<N>.tmp`` then os.rename'd — a crash mid-write
  never corrupts the latest checkpoint;
* validated: manifest carries a sha256 of the array payload; restore skips
  checkpoints that fail the hash (torn writes on real filesystems);
* elastic: arrays are stored in logical (unsharded) layout with their axis
  metadata, so restore re-shards onto ANY mesh (the elastic-scaling path:
  checkpoints written on 512 chips restore onto 256, or onto 1 CPU here);
* keep-N garbage collection.

On a real multi-host pod each host writes its addressable shards under
``step_<N>/shard_<p>`` and the manifest merges them; the single-process
container exercises the full-array path of the same format.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        if hasattr(tree, "_fields"):  # NamedTuple: record field names too
            pass
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        if hasattr(template, "_fields"):
            return type(template)(*vals)
        return type(template)(vals)
    if template is None:
        return None
    return flat[prefix[:-1]]


_NATIVE = {"float32", "float64", "int32", "int64", "uint32", "uint8",
           "int8", "int16", "uint16", "uint64", "bool", "float16"}


def _encode(a: np.ndarray) -> np.ndarray:
    """npz only round-trips native numpy dtypes; store others (bfloat16,
    fp8, ...) as raw same-width uint views — the manifest keeps the truth."""
    if str(a.dtype) in _NATIVE:
        return a
    return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[a.dtype.itemsize])


def _decode(a: np.ndarray, dtype: str) -> np.ndarray:
    if str(a.dtype) == dtype:
        return a
    import ml_dtypes  # jax dependency; registers bfloat16 & fp8 dtypes
    return a.view(np.dtype(dtype))


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **{k: _encode(v) for k, v in arrays.items()})
    digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "sha256": digest,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def _valid(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        digest = hashlib.sha256(
            open(os.path.join(path, "arrays.npz"), "rb").read()).hexdigest()
        return digest == manifest["sha256"]
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def latest_valid_step(ckpt_dir: str) -> int | None:
    """Newest checkpoint that passes hash validation (crash recovery)."""
    for s in sorted(all_steps(ckpt_dir), reverse=True):
        if _valid(os.path.join(ckpt_dir, f"step_{s}")):
            return s
    return None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Restore into the structure of ``template``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, device_put re-shards onto
    the current mesh — the elastic-restore path."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} failed validation")
    manifest = read_manifest(ckpt_dir, step)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: _decode(z[k], manifest["dtypes"][k]) for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else
            jax.device_put(a), tree, shardings)
    return tree


def read_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step}",
                           "manifest.json")) as f:
        return json.load(f)
