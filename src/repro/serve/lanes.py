"""Query lanes: a batch of B traversals vmapped through the engine round.

The Dalorex machine of :mod:`repro.core.engine` runs ONE program over one
resident graph.  A serving deployment answers many point queries (BFS /
SSSP sources) against that same graph; PIUMA's answer to small-message
underutilization is many concurrent threads sharing one memory system, and
the software analogue here is a *query-lane axis*: the per-round function
built by :func:`repro.core.engine.make_round` is ``jax.vmap``-ed over a
leading ``(B,)`` axis, so B independent traversals share the resident
graph shard, the round loop, the NoC and the TSU.

Bit-identity contract.  Each lane's trajectory is EXACTLY the solo run's:

* ``vmap`` preserves per-lane computation (the graph ``shard`` is closed
  over by the round, broadcast — never stacked);
* a lane whose own pending-work signal (:func:`repro.core.engine.
  pending_work`) hits zero is *frozen* by :func:`repro.core.engine.
  lane_select` — its state, Stats and Kahan compensation stop evolving,
  exactly as if its solo ``while_loop`` had exited.

So per-lane values AND every per-lane Stats field (rounds, msgs, cycles,
energy, link telemetry, ...) are bit-identical to B separate single-query
runs, on both execution backends (xla / pallas — the Pallas kernels take
the extra lane axis through ``pallas_call``'s batching rule as a grid
dimension) and both comm backends (LocalComm / shard_map).  The batch
finishes in ``max_i rounds_i`` shared rounds instead of ``sum_i rounds_i``
sequential ones — the whole point (tests/test_serve.py pins both).

Batch clock.  Lanes time-multiplex the tiles, so the *batch* makespan is
priced per round as the fixed round overhead paid once plus every active
lane's marginal work::

    cyc_round = t_round + sum over active lanes of (d_cyc_lane - t_round)

and batch energy re-apportions static leakage onto that shared makespan
(each lane's accumulator priced leakage over its own ``d_cyc``; the batch
pays it once over ``cyc_round``)::

    en_round = sum(d_en_lane - leak_pj(T, d_cyc_lane)) + leak_pj(T, cyc_round)

At B=1 both degenerate to the solo accumulators; at B>1 the batch clock
grows sublinearly in B — the amortization fig12 measures.  Both are
Kahan-compensated like the engine's own accumulators.

``done_round`` / ``done_cycle`` record, per lane, the shared round index
and batch-clock value at which the lane finished — the completion side of
the front end's enqueue -> admit -> complete latency accounting
(:mod:`repro.serve.frontend`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import AxisComm, LocalComm, shard_map_compat
from repro.core.engine import (EngineConfig, EngineState, GraphShard, Stats,
                               init_state, lane_select, make_round,
                               pending_work)
from repro.core.graph import PartitionedGraph
from repro.core.program import CLASSIC, as_program
from repro.noc import make_network
from repro.perf import leak_pj
from repro.trace.buffer import zero_trace


class LaneCarry(NamedTuple):
    """The batched round-loop carry: everything lane-led ``(B, ...)``
    except the shared batch counters (scalar)."""

    st: EngineState       # lane-led engine state
    stats: Stats          # lane-led per-query Stats
    kcomp: tuple          # ((B,) f32, (B,) f32) per-lane Kahan compensation
    pending: jax.Array    # (B,) i32 — per-lane global pending work
    rounds: jax.Array     # () i32 — shared batch rounds so far
    clock: jax.Array      # () f32 — batch makespan, modeled cycles
    clock_c: jax.Array    # () f32 — Kahan compensation of `clock`
    energy: jax.Array     # () f32 — batch energy, pJ
    energy_c: jax.Array   # () f32 — Kahan compensation of `energy`
    done_round: jax.Array  # (B,) i32 — batch round a lane finished at
                           # (-1 = still running / never finished)
    done_cycle: jax.Array  # (B,) f32 — batch clock at lane completion
    halt: jax.Array       # () bool — segment stop flag (continuous mode)
    trace: tuple = ()     # lane-led (B, ...) TraceBuf when cfg.trace,
                          # else the empty pytree (no extra carry leaves)


def lane_state(comm, cfg: EngineConfig, v_chunk: int, value, frontier, alg,
               acc=None) -> EngineState:
    """Vmapped :func:`repro.core.engine.init_state` over the leading lane
    axis: ``value``/``frontier`` are ``(B, T, v_chunk)`` under LocalComm,
    ``(B, v_chunk)`` under AxisComm."""
    prog = as_program(alg)
    if acc is None:
        acc = jnp.zeros_like(value)
    return jax.vmap(
        lambda v, f, a: init_state(comm, cfg, v_chunk, v, f, prog, a)
    )(value, frontier, acc)


def lane_carry(comm, net, cfg: EngineConfig, prog, st: EngineState
               ) -> LaneCarry:
    """A fresh carry for a lane-led state: per-lane pending computed with
    the engine's own :func:`pending_work` definition, zero Stats broadcast
    to the lane axis, batch clocks at zero.  Lanes that start with no
    pending work (padding lanes) are born finished: ``done_round = 0``."""
    prog = as_program(prog)
    pend0 = jax.vmap(
        lambda s: comm.to_global(comm.psum(comm.run(pending_work, s))))(st)
    B = pend0.shape[0]
    z = Stats.zero(net.num_links, net.max_hops, len(prog.channels),
                   net.max_die_crossings)
    stats = jax.tree.map(lambda x: jnp.broadcast_to(x, (B,) + x.shape), z)
    zf = jnp.zeros((B,), jnp.float32)
    z0 = jnp.zeros((), jnp.float32)
    trace = ()
    if cfg.trace:  # each lane records its own ring, frozen when the lane is
        tb = zero_trace(cfg, comm.size, prog)
        trace = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (B,) + x.shape), tb)
    return LaneCarry(
        st=st, stats=stats, kcomp=(zf, zf), pending=pend0,
        rounds=jnp.zeros((), jnp.int32),
        clock=z0, clock_c=z0, energy=z0, energy_c=z0,
        done_round=jnp.where(pend0 > 0, jnp.int32(-1), jnp.int32(0)),
        done_cycle=jnp.zeros((B,), jnp.float32),
        halt=jnp.zeros((), bool), trace=trace)


def lane_loop(comm, net, cfg: EngineConfig, prog, e_chunk: int, v_chunk: int,
              shard: GraphShard, carry: LaneCarry,
              stop_on_finish: bool = False) -> LaneCarry:
    """Run the batched round loop until every lane is idle (or max_rounds).

    One shared ``lax.while_loop`` drives ``jax.vmap(rnd)``; finished lanes
    are frozen by :func:`lane_select` so their trajectories stay
    bit-identical to solo runs.  With ``stop_on_finish=True`` the loop
    additionally exits the round ANY active lane completes — the
    continuous-batching segment runner: the host then recycles the freed
    lane(s) and resumes with the same carry (``halt`` is cleared by
    :func:`recycle_lanes`).
    """
    prog = as_program(prog)
    rnd = make_round(comm, net, cfg, prog, e_chunk, v_chunk, shard)
    vrnd = jax.vmap(rnd)
    pp = cfg.perf
    T = comm.size

    def kahan(total, comp, inc):
        y = inc - comp
        t = total + y
        return t, (t - total) - y

    def cond(c: LaneCarry):
        return ((c.pending > 0).any() & (c.rounds < cfg.max_rounds)
                & ~c.halt)

    def body(c: LaneCarry):
        active = c.pending > 0
        st2, stats2, kcomp2, tbuf2, pend2 = vrnd(c.st, c.stats, c.kcomp,
                                                 c.trace)
        st = lane_select(active, c.st, st2)
        stats = lane_select(active, c.stats, stats2)
        kcomp = lane_select(active, c.kcomp, kcomp2)
        trace = lane_select(active, c.trace, tbuf2)
        pending = jnp.where(active, pend2, c.pending)
        rounds = c.rounds + 1
        # batch clock: realized per-lane increments (0 for frozen lanes);
        # the shared round pays t_round once, then each active lane's
        # marginal cost on top.
        d_cyc = stats.cycles - c.stats.cycles
        d_en = stats.energy_pj - c.stats.energy_pj
        tr = jnp.float32(pp.t_round)
        cyc_round = tr + (d_cyc - jnp.where(active, tr, 0.0)).sum()
        en_round = ((d_en - leak_pj(pp, T, d_cyc)).sum()
                    + leak_pj(pp, T, cyc_round))
        clock, clock_c = kahan(c.clock, c.clock_c, cyc_round)
        energy, energy_c = kahan(c.energy, c.energy_c, en_round)
        newly = active & (pending == 0)
        done_round = jnp.where(newly, rounds, c.done_round)
        done_cycle = jnp.where(newly, clock, c.done_cycle)
        halt = newly.any() if stop_on_finish else c.halt
        return LaneCarry(st, stats, kcomp, pending, rounds, clock, clock_c,
                         energy, energy_c, done_round, done_cycle, halt,
                         trace)

    return jax.lax.while_loop(cond, body, carry)


# --------------------------------------------------------------------------
# Jitted entry points: LocalComm emulation and shard_map SPMD.
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("prog", "cfg", "T", "e_chunk", "v_chunk"))
def local_lanes_call(prog, cfg: EngineConfig, T: int, e_chunk: int,
                     v_chunk: int, shard: GraphShard, value, frontier, acc
                     ) -> LaneCarry:
    """Full batched run under LocalComm: ``(B, T, v_chunk)`` value /
    frontier / acc in, final :class:`LaneCarry` out."""
    comm = LocalComm(T)
    net = make_network(cfg, T)
    st = lane_state(comm, cfg, v_chunk, value, frontier, prog, acc)
    carry = lane_carry(comm, net, cfg, prog, st)
    return lane_loop(comm, net, cfg, prog, e_chunk, v_chunk, shard, carry)


@partial(jax.jit, static_argnames=("prog", "cfg", "T", "e_chunk", "v_chunk",
                                   "stop_on_finish"))
def local_lanes_segment(prog, cfg: EngineConfig, T: int, e_chunk: int,
                        v_chunk: int, shard: GraphShard, carry: LaneCarry,
                        stop_on_finish: bool = True) -> LaneCarry:
    """Resume a batched run from an existing carry, stopping at the first
    round any active lane finishes — the continuous-batching segment."""
    comm = LocalComm(T)
    net = make_network(cfg, T)
    return lane_loop(comm, net, cfg, prog, e_chunk, v_chunk, shard, carry,
                     stop_on_finish=stop_on_finish)


def spmd_lanes_call(pg: PartitionedGraph, prog, cfg: EngineConfig, value,
                    frontier, mesh, axis: str = "x", acc=None):
    """The batched run as true SPMD under shard_map: the tile axis is
    sharded over ``axis`` of ``mesh``, the lane axis is replicated (every
    device runs all B lanes of its own tile row — the same layout a real
    grid would use, queries resident on every tile).

    ``value``/``frontier``/``acc``: ``(B, T, v_chunk)``.  Returns
    ``(values (B, T, v_chunk), stats lane-led, rounds, clock, energy,
    done_round, done_cycle, trace)`` — ``trace`` is the lane-led
    :class:`repro.trace.TraceBuf` when ``cfg.trace``, else ``None``.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    T = pg.T
    prog = as_program(prog)
    prog.validate(cfg, T, pg.e_chunk, pg.v_chunk)
    comm = AxisComm(axis, T)
    net = make_network(cfg, T)
    if acc is None:
        acc = jnp.zeros_like(value)
    spec2 = P(axis, None)
    spec3 = P(None, axis, None)

    def body(ptr_start, deg, edge_dst, edge_val, value, frontier, acc):
        shard = GraphShard(ptr_start[0], deg[0], edge_dst[0], edge_val[0])
        st = lane_state(comm, cfg, pg.v_chunk, value[:, 0], frontier[:, 0],
                        prog, acc[:, 0])
        carry = lane_carry(comm, net, cfg, prog, st)
        out = lane_loop(comm, net, cfg, prog, pg.e_chunk, pg.v_chunk, shard,
                        carry)
        return (out.st.value[:, None], out.stats, out.rounds, out.clock,
                out.energy, out.done_round, out.done_cycle, out.trace)

    stats_spec = jax.tree.map(lambda _: P(), Stats.zero())
    # lane-led trace rings hold only globals — replicated, like Stats
    trace_spec = jax.tree.map(lambda _: P(), zero_trace(cfg, T, prog)) \
        if cfg.trace else ()
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec2,) * 4 + (spec3,) * 3,
        out_specs=(spec3, stats_spec, P(), P(), P(), P(), P(), trace_spec))
    args = [jax.device_put(a, NamedSharding(mesh, spec2)) for a in
            (pg.ptr_start, pg.deg, pg.edge_dst, pg.edge_val)]
    args += [jax.device_put(a, NamedSharding(mesh, spec3)) for a in
             (value, frontier, acc)]
    return jax.jit(fn)(*args)


# --------------------------------------------------------------------------
# Host-side batch construction and the one-shot multi-source driver.
# --------------------------------------------------------------------------

def batch_min_state(pg: PartitionedGraph, sources):
    """``(B, T, v_chunk)`` value/frontier for a batch of min-app sources.

    ``sources[i] < 0`` makes lane i a *padding lane*: all-INF values and an
    empty frontier, so it is born idle, frozen from round 0, and costs the
    batch nothing — the front end pads partial batches with these.
    """
    B = len(sources)
    value = np.full((B, pg.T, pg.v_chunk),
                    np.float32(np.finfo(np.float32).max), np.float32)
    frontier = np.zeros((B, pg.T, pg.v_chunk), bool)
    for i, s in enumerate(sources):
        if s < 0:
            continue
        p = int(pg.place[int(s)])
        t, l = divmod(p, pg.v_chunk)
        value[i, t, l] = 0.0
        frontier[i, t, l] = True
    return jnp.asarray(value), jnp.asarray(frontier)


def lane_values(pg: PartitionedGraph, value) -> np.ndarray:
    """One lane's ``(T, v_chunk)`` placed-space values -> ``(V,)`` f64 in
    original vertex order, unreached slots mapped to +inf (the min-app
    convention of :func:`repro.core.algorithms.bfs`)."""
    flat = np.asarray(value).reshape(-1)
    out = flat[np.asarray(pg.place)].astype(np.float64)
    out[out >= np.float32(np.finfo(np.float32).max)] = np.inf
    return out


@dataclasses.dataclass
class BatchResult:
    """One batched multi-source run, host-side."""

    values: np.ndarray       # (B, V) f64 in original vertex order
    stats: Stats             # lane-led (B, ...) per-query Stats
    total_rounds: int        # shared batch rounds (== max over lane rounds)
    batch_cycles: float      # batch-clock makespan, modeled cycles
    batch_energy_pj: float   # batch energy on the shared makespan
    done_round: np.ndarray   # (B,) i32
    done_cycle: np.ndarray   # (B,) f32
    sources: np.ndarray      # (B,) the admitted sources (-1 = padding)
    trace: object = None     # lane-led (B, ...) TraceBuf when cfg.trace

    @property
    def seq_rounds(self) -> int:
        """What B sequential solo runs would have cost in rounds (valid
        because each lane's Stats are bit-identical to its solo run)."""
        return int(np.asarray(self.stats.rounds).sum())


def multi_source(pg: PartitionedGraph, app: str, sources,
                 cfg: EngineConfig = EngineConfig(), mesh=None
                 ) -> BatchResult:
    """Answer a batch of point queries (``app`` in "bfs" / "sssp") over the
    resident graph in one shared batched run.

    ``mesh=None`` runs the LocalComm emulation; a mesh runs shard_map SPMD.
    Per-query results are bit-identical to solo :func:`repro.core.
    algorithms.bfs` / ``sssp`` runs at the same ``cfg``.
    """
    if app not in ("bfs", "sssp"):
        raise ValueError(f"multi_source serves point queries (bfs/sssp), "
                         f"got {app!r}")
    alg_spec = CLASSIC[app]
    sources = np.asarray(sources, np.int64)
    value, frontier = batch_min_state(pg, sources)
    if mesh is None:
        shard = GraphShard(pg.ptr_start, pg.deg, pg.edge_dst, pg.edge_val)
        prog = as_program(alg_spec)
        prog.validate(cfg, pg.T, pg.e_chunk, pg.v_chunk)
        out = local_lanes_call(prog, cfg, pg.T, pg.e_chunk, pg.v_chunk,
                               shard, value, frontier,
                               jnp.zeros_like(value))
        vals, stats = out.st.value, out.stats
        rounds, clock, energy = out.rounds, out.clock, out.energy
        done_round, done_cycle = out.done_round, out.done_cycle
        trace = out.trace if cfg.trace else None
    else:
        (vals, stats, rounds, clock, energy, done_round, done_cycle,
         trace) = spmd_lanes_call(pg, alg_spec, cfg, value, frontier, mesh)
        if not cfg.trace:
            trace = None
    B = len(sources)
    flat = np.asarray(vals).reshape(B, -1)
    values = flat[:, np.asarray(pg.place)].astype(np.float64)
    values[values >= np.float32(np.finfo(np.float32).max)] = np.inf
    return BatchResult(
        values=values, stats=jax.tree.map(np.asarray, stats),
        total_rounds=int(rounds), batch_cycles=float(clock),
        batch_energy_pj=float(energy),
        done_round=np.asarray(done_round), done_cycle=np.asarray(done_cycle),
        sources=sources, trace=trace)
