"""Serving front end: request queue -> batches -> lanes -> latency rows.

The machine side (:mod:`repro.serve.lanes`) answers a fixed batch of B
sources; this module is the *service* wrapped around it: a request queue
admits sources as they arrive, forms fixed-width batches (padding partial
batches with idle lanes), drives the batched round loop, and streams back
per-query results with latency accounted on the perf model's cycle clock
— every timestamp below is modeled machine cycles, not host wall time.

Latency accounting (per query)::

    enqueue_cycle   the request arrives (the arrival process)
    admit_cycle     its batch forms / its lane is recycled to it
    complete_cycle  its lane's pending work hits zero (batch clock)

    wait    = admit - enqueue      (queueing delay)
    latency = complete - enqueue   (what the client sees)

Two batching policies:

* ``"static"`` — classic fixed batches: admit up to ``width`` arrived
  requests, run the batch TO COMPLETION, advance the clock by the batch
  makespan, repeat.  Stragglers hold the whole batch (the head-of-line
  blocking fig12's latency columns expose).  Works on both comm backends
  (LocalComm and shard_map SPMD).
* ``"continuous"`` — continuous batching: the round loop is run in
  *segments* that stop the moment any lane finishes; the freed lane is
  immediately recycled to the next queued request (state re-initialized in
  place, its channel queues reset with :func:`repro.core.queues.
  queue_clear`, its Stats slice zeroed) while the other lanes keep their
  in-flight traversals.  LocalComm only (the host sits in the admit loop).

Both policies price time on the shared *batch clock* of
:mod:`repro.serve.lanes` (lanes time-multiplex the tiles; the fixed round
overhead is paid once per round), so a wider batch amortizes rounds and a
recycled lane never waits for its cohort.
"""
from __future__ import annotations

import dataclasses
from collections import deque
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import LocalComm
from repro.core.engine import EngineConfig, EngineState
from repro.core.graph import PartitionedGraph
from repro.core.program import CLASSIC, as_program
from repro.core.queues import Queue, queue_clear
from repro.serve.lanes import (GraphShard, LaneCarry, batch_min_state,
                               lane_carry, lane_state, lane_values,
                               local_lanes_segment, multi_source)


def arrival_cycles(n: int, pattern: str = "burst", gap: float = 0.0,
                   seed: int = 0) -> np.ndarray:
    """Enqueue timestamps (modeled cycles) for ``n`` requests.

    ``pattern``: "burst" (all at cycle 0 — an offline batch), "uniform"
    (one every ``gap`` cycles — a paced open loop), or "poisson"
    (exponential interarrivals with mean ``gap`` — an open loop with
    bursts).  Deterministic at a fixed ``seed``.
    """
    if pattern == "burst":
        return np.zeros(n, np.float64)
    if gap <= 0:
        raise ValueError(f"{pattern!r} arrivals need gap > 0 cycles")
    if pattern == "uniform":
        return gap * np.arange(n, dtype=np.float64)
    if pattern == "poisson":
        rng = np.random.default_rng(seed)
        return np.cumsum(rng.exponential(gap, size=n))
    raise ValueError(f"unknown arrival pattern {pattern!r}")


@dataclasses.dataclass
class QueryRecord:
    """One served query, timestamps in modeled cycles."""

    qid: int
    source: int
    enqueue_cycle: float
    admit_cycle: float
    complete_cycle: float
    rounds: int     # the lane's own rounds (== its solo run's rounds)
    edges: int      # the lane's edges_scanned
    values: np.ndarray = None  # (V,) f64 result, original vertex order

    @property
    def wait(self) -> float:
        return self.admit_cycle - self.enqueue_cycle

    @property
    def latency(self) -> float:
        return self.complete_cycle - self.enqueue_cycle


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one serving run; throughput on the modeled clock."""

    app: str
    policy: str
    width: int
    arrival: str
    records: list
    batches: int
    total_cycles: float      # serving makespan (batch clock + idle gaps)
    total_energy_pj: float
    total_rounds: int        # shared rounds actually executed
    seq_rounds: int          # what solo runs would have cost (sum of
                             # per-lane rounds — each lane == its solo run)
    drops: int = 0           # summed over lanes; MUST be 0 (backpressure)
    f_ghz: float = 1.0
    migrated_vertices: int = 0  # vertices moved by between-batch adaptation

    @property
    def queries(self) -> int:
        return len(self.records)

    @property
    def time_s(self) -> float:
        return self.total_cycles / (self.f_ghz * 1e9)

    @property
    def qps(self) -> float:
        return self.queries / self.time_s if self.time_s > 0 else 0.0

    @property
    def j_per_query(self) -> float:
        return (self.total_energy_pj * 1e-12 / self.queries
                if self.queries else 0.0)

    @property
    def edges_total(self) -> int:
        return sum(r.edges for r in self.records)

    @property
    def gteps(self) -> float:
        return (self.edges_total / self.time_s / 1e9
                if self.time_s > 0 else 0.0)

    def latency_cycles(self, q: float) -> float:
        """Latency percentile (0..100) over the served queries, cycles."""
        return float(np.percentile([r.latency for r in self.records], q))

    def row(self) -> dict:
        row = {
            "app": self.app, "policy": self.policy, "width": self.width,
            "arrival": self.arrival, "queries": self.queries,
            "batches": self.batches, "rounds": self.total_rounds,
            "seq_rounds": self.seq_rounds,
            "cycles": int(round(self.total_cycles)),
            "energy_pj": round(self.total_energy_pj, 1),
            "drops": self.drops,
            "qps": round(self.qps, 1),
            "gteps": round(self.gteps, 6),
            "j_per_query": round(self.j_per_query * 1e12, 1),  # pJ/query
            "lat_p50": int(round(self.latency_cycles(50))),
            "lat_p95": int(round(self.latency_cycles(95))),
            "lat_max": int(round(self.latency_cycles(100))),
        }
        if self.migrated_vertices:  # additive: pre-adaptive rows unchanged
            row["migrated_vertices"] = self.migrated_vertices
        return row


@jax.jit
def _recycle(carry: LaneCarry, lane, value, frontier) -> LaneCarry:
    """Re-initialize ONE lane of the carry in place for a fresh query:
    min-app value/frontier set, acc and BSP frontier zeroed, channel
    queues reset (:func:`queue_clear` — bit-equal to freshly made ones),
    Stats slice and Kahan compensation zeroed, pending recomputed, and the
    segment ``halt`` flag cleared so the loop resumes."""
    st = carry.st
    cleared = tuple(queue_clear(Queue(q.data[lane], q.count[lane]))
                    for q in st.queues)
    st = EngineState(
        value=st.value.at[lane].set(value),
        acc=st.acc.at[lane].set(0.0),
        frontier=st.frontier.at[lane].set(frontier),
        next_frontier=st.next_frontier.at[lane].set(False),
        queues=tuple(Queue(q.data.at[lane].set(c.data),
                           q.count.at[lane].set(c.count))
                     for q, c in zip(st.queues, cleared)),
        net_pressure=st.net_pressure.at[lane].set(0))
    stats = jax.tree.map(lambda s: s.at[lane].set(jnp.zeros_like(s[lane])),
                         carry.stats)
    kcomp = jax.tree.map(lambda k: k.at[lane].set(0.0), carry.kcomp)
    # the recycled lane's flight-recorder ring starts over too (cursor 0,
    # every slot marked empty) so its trace is the fresh query's alone
    trace = carry.trace
    if len(trace):  # a lane-led TraceBuf (cfg.trace)
        trace = jax.tree.map(
            lambda s: s.at[lane].set(jnp.zeros_like(s[lane])), trace)
        trace = trace._replace(
            round_id=trace.round_id.at[lane].set(-1))
    # fresh lane: queues empty, so pending is the frontier population
    pend = frontier.sum(dtype=jnp.int32)
    return carry._replace(
        st=st, stats=stats, kcomp=kcomp, trace=trace,
        pending=carry.pending.at[lane].set(pend),
        done_round=carry.done_round.at[lane].set(-1),
        done_cycle=carry.done_cycle.at[lane].set(0.0),
        halt=jnp.zeros((), bool))


class Frontend:
    """The serving loop over one resident partitioned graph.

    >>> fe = Frontend(pg, app="bfs", cfg=cfg, width=8)
    >>> report = fe.serve(sources, arrival="poisson", gap=5e4)
    """

    def __init__(self, pg: PartitionedGraph, app: str = "bfs",
                 cfg: EngineConfig = EngineConfig(), width: int = 8,
                 policy: str = "static", mesh=None, graph=None):
        if app not in ("bfs", "sssp"):
            raise ValueError(f"servable point-query apps: bfs/sssp, "
                             f"got {app!r}")
        if policy not in ("static", "continuous"):
            raise ValueError(f"unknown policy {policy!r}")
        if policy == "continuous" and mesh is not None:
            raise ValueError("continuous batching is LocalComm-only "
                             "(the host drives the admit loop)")
        if width < 1:
            raise ValueError("width must be >= 1")
        if cfg.adapt and graph is None:
            raise ValueError("cfg.adapt needs graph= (the host CSR) to "
                             "re-deal edge segments between batches")
        if cfg.adapt and policy != "static":
            raise ValueError("between-batch adaptation is static-policy "
                             "only (continuous lanes are never quiescent)")
        self.pg = pg
        self.app = app
        self.cfg = cfg
        self.width = width
        self.policy = policy
        self.mesh = mesh
        self.graph = graph          # host CSR; needed when cfg.adapt
        self.migrated_vertices = 0  # total moved by between-batch plans
        self.prog = as_program(CLASSIC[app])
        self.prog.validate(cfg, pg.T, pg.e_chunk, pg.v_chunk)

    # -- public ------------------------------------------------------------

    def serve(self, sources, arrival: str = "burst", gap: float = 0.0,
              seed: int = 0) -> ServeReport:
        """Serve ``sources`` (original vertex ids) arriving per
        ``arrival``/``gap`` (see :func:`arrival_cycles`); returns the
        aggregate report with one :class:`QueryRecord` per query."""
        sources = np.asarray(sources, np.int64)
        enq = arrival_cycles(len(sources), arrival, gap, seed)
        queue = deque(
            (i, int(s), float(t)) for i, (s, t) in enumerate(zip(sources,
                                                                 enq)))
        serve = (self._serve_static if self.policy == "static"
                 else self._serve_continuous)
        migrated0 = self.migrated_vertices
        records, batches, cyc, en, rounds, seq, drops = serve(queue)
        records.sort(key=lambda r: r.qid)
        return ServeReport(
            app=self.app, policy=self.policy, width=self.width,
            arrival=arrival, records=records, batches=batches,
            total_cycles=cyc, total_energy_pj=en, total_rounds=rounds,
            seq_rounds=seq, drops=drops, f_ghz=self.cfg.perf.f_ghz,
            migrated_vertices=self.migrated_vertices - migrated0)

    # -- between-batch adaptation (repro.place) ----------------------------

    def _maybe_adapt(self, res):
        """Relabel the resident partition from the finished batch's
        telemetry (lane-led trace rings summed into one busy vector; the
        planner's static in-degree fallback when tracing is off).  The
        batch boundary is the serving quiescent point — every lane has
        drained — so the migration is a pure relabeling and later queries
        see bit-identical values.  Returns the priced ``(cycles, pJ)`` of
        the move, charged to the serving clock by the caller."""
        from repro.perf.model import migration_cost
        from repro.place import (adapt_partition, cfg_tile_die,
                                 migration_words, score_tiles)
        busy = None
        if res.trace is not None:
            from repro.trace.export import lane_trace
            busy = sum(score_tiles(lane_trace(res.trace, lane))
                       for lane in range(self.width))
        old = self.pg
        pg2, plan = adapt_partition(self.graph, old, self.cfg, busy=busy)
        if not plan.num_pairs:
            return 0.0, 0.0
        tile_die = cfg_tile_die(self.cfg, old.T)
        wi, wc = migration_words(old, plan, tile_die)
        cyc, pj = migration_cost(self.cfg.perf, wi, wc)
        self.migrated_vertices += plan.moved_vertices(old)
        self.pg = pg2
        # e_chunk can change in the aligned edge modes: re-check sizing
        self.prog.validate(self.cfg, pg2.T, pg2.e_chunk, pg2.v_chunk)
        return cyc, pj

    # -- static batches ----------------------------------------------------

    def _serve_static(self, queue):
        records, batches = [], 0
        now = 0.0
        energy = 0.0
        rounds = seq = drops = 0
        while queue:
            # the batch forms when its first request has arrived
            now = max(now, queue[0][2])
            batch = []
            while queue and len(batch) < self.width and queue[0][2] <= now:
                batch.append(queue.popleft())
            srcs = [s for _, s, _ in batch] + [-1] * (self.width -
                                                      len(batch))
            res = multi_source(self.pg, self.app, srcs, self.cfg, self.mesh)
            lane_rounds = np.asarray(res.stats.rounds)
            lane_edges = np.asarray(res.stats.edges_scanned)
            for lane, (qid, s, t_enq) in enumerate(batch):
                records.append(QueryRecord(
                    qid=qid, source=s, enqueue_cycle=t_enq,
                    admit_cycle=now,
                    complete_cycle=now + float(res.done_cycle[lane]),
                    rounds=int(lane_rounds[lane]),
                    edges=int(lane_edges[lane]),
                    values=res.values[lane]))
            now += res.batch_cycles
            energy += res.batch_energy_pj
            rounds += res.total_rounds
            seq += res.seq_rounds
            drops += int(np.asarray(res.stats.drops).sum())
            batches += 1
            if (self.cfg.adapt and queue
                    and batches % max(self.cfg.adapt_every, 1) == 0):
                mig_cyc, mig_pj = self._maybe_adapt(res)
                now += mig_cyc
                energy += mig_pj
        return records, batches, now, energy, rounds, seq, drops

    # -- continuous batching (lane recycling) ------------------------------

    def _serve_continuous(self, queue):
        pg, cfg, W = self.pg, self.cfg, self.width
        shard = GraphShard(pg.ptr_start, pg.deg, pg.edge_dst, pg.edge_val)
        comm = LocalComm(pg.T)
        from repro.noc import make_network
        net = make_network(cfg, pg.T)

        # born idle: W padding lanes; the admit loop below fills them
        value, frontier = batch_min_state(pg, [-1] * W)
        st = lane_state(comm, cfg, pg.v_chunk, value, frontier, self.prog)
        carry = lane_carry(comm, net, cfg, self.prog, st)
        lane_qid = [-1] * W          # qid in flight per lane (-1 = idle)
        lane_meta = [None] * W       # (qid, source, enqueue, admit)
        records, batches = [], 0
        drops = 0
        now = 0.0                    # absolute serving clock (cycles)

        def admit():
            nonlocal carry, batches, now
            pending = np.asarray(carry.pending)
            idle = [i for i in range(W) if lane_qid[i] < 0]
            # a fully idle machine fast-forwards to the next arrival
            if queue and len(idle) == W and queue[0][2] > now:
                now = queue[0][2]
            admitted = 0
            for lane in idle:
                if not queue or queue[0][2] > now:
                    break
                assert pending[lane] == 0
                qid, s, t_enq = queue.popleft()
                v1, f1 = batch_min_state(pg, [s])
                carry = _recycle(carry, jnp.int32(lane), v1[0], f1[0])
                lane_qid[lane] = qid
                lane_meta[lane] = (qid, s, t_enq, now)
                admitted += 1
            if admitted:
                batches += 1  # here: one lane-refill event
            return admitted

        admit()
        while any(q >= 0 for q in lane_qid):
            prev_clock = float(carry.clock)
            # clear the segment stop flag even when nothing was admitted
            # (no arrival yet): the remaining in-flight lanes must resume
            carry = carry._replace(halt=jnp.zeros((), bool))
            carry = local_lanes_segment(self.prog, cfg, pg.T, pg.e_chunk,
                                        pg.v_chunk, shard, carry)
            now += float(carry.clock) - prev_clock
            pending = np.asarray(carry.pending)
            lane_rounds = np.asarray(carry.stats.rounds)
            lane_edges = np.asarray(carry.stats.edges_scanned)
            lane_drops = np.asarray(carry.stats.drops)
            for lane in range(W):
                if lane_qid[lane] >= 0 and pending[lane] == 0:
                    qid, s, t_enq, t_admit = lane_meta[lane]
                    records.append(QueryRecord(
                        qid=qid, source=s, enqueue_cycle=t_enq,
                        admit_cycle=t_admit, complete_cycle=now,
                        rounds=int(lane_rounds[lane]),
                        edges=int(lane_edges[lane]),
                        values=lane_values(pg, carry.st.value[lane])))
                    drops += int(lane_drops[lane])
                    lane_qid[lane] = -1
            admit()
        total_rounds = int(carry.rounds)
        # each lane is bit-identical to its solo run, so the sequential
        # cost is just the sum of the per-record round counts
        seq = sum(r.rounds for r in records)
        return (records, batches, now, float(carry.energy), total_rounds,
                seq, drops)
