"""CLI: serve a stream of point queries against a resident preset graph.

    PYTHONPATH=src python -m repro.serve --preset rmat-small --queries 64 \
        --batch 16 --app bfs --arrival poisson --gap 5e4 --policy static

Prints the aggregate throughput/latency report (modeled cycles) and, with
``--per-query``, one line per served query.
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Batched query serving over a resident graph")
    ap.add_argument("--preset", default="rmat-small",
                    help="repro.configs.dalorex_graph preset")
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8, help="lane width B")
    ap.add_argument("--app", default="bfs", choices=("bfs", "sssp"))
    ap.add_argument("--arrival", default="burst",
                    choices=("burst", "uniform", "poisson"))
    ap.add_argument("--gap", type=float, default=0.0,
                    help="mean interarrival gap, modeled cycles")
    ap.add_argument("--policy", default="static",
                    choices=("static", "continuous"))
    ap.add_argument("--backend", default=None, choices=("xla", "pallas"),
                    help="engine backend override (default: preset's)")
    ap.add_argument("--noc", default=None,
                    help="NoC backend override (default: preset's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--per-query", action="store_true")
    args = ap.parse_args()

    from repro.configs.dalorex_graph import get_workload
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges
    from repro.serve import Frontend

    wl = get_workload(args.preset)
    n, src, dst, val = rmat_edges(wl.scale, edge_factor=wl.edge_factor,
                                  seed=0)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=wl.tiles, scheme=wl.placement)
    # size the channel queues from the engine's own worst-case inflow
    # bounds (mirrors benchmarks/common.engine_cfg without importing the
    # benchmarks tree from inside the package)
    base = dict(f_pop=32, r_pop=32, u_pop=64, max_t2=16,
                cap_route_range=8, cap_route_update=32,
                max_rounds=200_000, backend=args.backend or wl.backend,
                noc=args.noc or wl.noc)
    if base["noc"] == "hier":
        base["ndies_y"], base["ndies_x"] = wl.ndies
    rangeq, burst = EngineConfig(**base).min_caps(wl.tiles)
    cfg = EngineConfig(
        cap_rangeq=max(512, 1 << (rangeq - 1).bit_length()),
        cap_updq=max(8192, 1 << (burst - 1).bit_length()), **base)

    rng = np.random.default_rng(args.seed)
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    sources = rng.choice(np.flatnonzero(deg > 0), size=args.queries)

    fe = Frontend(pg, app=args.app, cfg=cfg, width=args.batch,
                  policy=args.policy)
    rep = fe.serve(sources, arrival=args.arrival, gap=args.gap,
                   seed=args.seed)

    print(f"# preset={args.preset} V={g.num_vertices} T={wl.tiles} "
          f"backend={cfg.backend} noc={cfg.noc}")
    print(",".join(f"{k}={v}" for k, v in rep.row().items()))
    if args.per_query:
        for r in rep.records:
            print(f"q{r.qid} src={r.source} enq={r.enqueue_cycle:.0f} "
                  f"admit={r.admit_cycle:.0f} "
                  f"done={r.complete_cycle:.0f} lat={r.latency:.0f} "
                  f"rounds={r.rounds} edges={r.edges}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
