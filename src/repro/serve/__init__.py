"""Multi-tenant query serving over the resident graph (DESIGN.md "Query
serving").

Layers:

* :mod:`repro.serve.lanes` — the machine side: a batch of B point queries
  vmapped through the engine round as *query lanes*, bit-identical per
  lane to B solo runs, priced on a shared batch clock.
* :mod:`repro.serve.frontend` — the service side: request queue, batch
  formation (static or continuous/lane-recycling), latency accounting on
  the modeled cycle clock.
* ``python -m repro.serve`` — the CLI (:mod:`repro.serve.__main__`).
"""
from repro.serve.frontend import (Frontend, QueryRecord, ServeReport,
                                  arrival_cycles)
from repro.serve.lanes import (BatchResult, LaneCarry, batch_min_state,
                               lane_carry, lane_loop, lane_state,
                               local_lanes_call, local_lanes_segment,
                               multi_source, spmd_lanes_call)

__all__ = [
    "BatchResult", "Frontend", "LaneCarry", "QueryRecord", "ServeReport",
    "arrival_cycles", "batch_min_state", "lane_carry", "lane_loop",
    "lane_state", "local_lanes_call", "local_lanes_segment", "multi_source",
    "spmd_lanes_call",
]
