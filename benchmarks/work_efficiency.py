"""Paper's work-efficiency discussion: edges explored vs the sequential
minimum, async (barrierless, may relax stale values) vs BSP."""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import engine_cfg, pick_root, rmat_graph, stats_row


def run(scale: int = 10, T: int = 16) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    pg = alg.prepare(g, T)
    rows = []
    for app in ("bfs", "sssp"):
        fn = alg.bfs if app == "bfs" else alg.sssp
        for mode in ("async", "bsp"):
            res = fn(pg, root, engine_cfg(mode=mode))
            s = stats_row(res.stats)
            rows.append({
                "bench": "work_eff", "app": app, "mode": mode,
                "edges_scanned": s["edges_scanned"],
                "edges_per_graph_edge": round(
                    s["edges_scanned"] / g.num_edges, 3),
                "rounds": s["rounds"],
            })
    return rows
