"""fig12: query-serving throughput — batch width x arrival pattern.

Beyond the paper's figures: the paper's machine runs ONE traversal; PR6's
serving subsystem (src/repro/serve/) batches B concurrent point queries
through the same engine as vmapped *query lanes*, so rounds, the NoC and
the TSU are amortized across a request batch.  This bench sweeps batch
width x arrival pattern (burst / uniform / poisson open loops) x batching
policy (static batches vs continuous lane recycling) and reports:

* ``qps``        queries per modeled second (the serving headline),
* ``gteps``      aggregate traversed-edges throughput on the same clock,
* ``j_per_query``  modeled picojoules per query (leakage priced once on
  the shared batch makespan, not per lane),
* ``lat_p50/p95/max``  enqueue -> complete latency in modeled cycles,
* ``rounds`` vs ``seq_rounds``  shared rounds executed vs what B solo
  runs would have cost (each lane is bit-identical to its solo run, so
  the sequential cost is exactly the sum of per-lane rounds).

The ``ok`` column asserts per-query values against the host oracle
(ref.bfs_ref / sssp_ref) and, for B > 1, the strictly-fewer-rounds
amortization claim.  Rows feed ``benchmarks/smoke.py`` (baseline-gated)
and the standalone ``BENCH_FIG12.json`` CI artifact.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import engine_cfg, rmat_graph
from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.serve import Frontend


def _sources(g, n: int, seed: int = 0) -> np.ndarray:
    """n query sources with out-edges (deterministic at a seed)."""
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    rng = np.random.default_rng(seed)
    return rng.choice(np.flatnonzero(deg > 0), size=n)


def _oracle(g, app: str, sources) -> dict:
    fn = ref.bfs_ref if app == "bfs" else ref.sssp_ref
    return {int(s): fn(g, int(s)) for s in set(int(s) for s in sources)}


def run(scale: int = 10, T: int = 16, queries: int = 64,
        widths=(1, 8, 64), app: str = "bfs",
        arrivals=("burst", "poisson"), gap: float = 20_000.0,
        backends=("xla",), continuous: bool = True,
        pallas_width: int = 0, seed: int = 0) -> list[dict]:
    """One row per (backend x width x arrival) static sweep, plus a
    continuous-batching row at the widest width, plus (``pallas_width>0``)
    one backend="pallas" row proving the lanes run on the tile-grid
    kernels too.  Rows are deterministic (modeled clock only, no wall
    time) — what smoke.py commits to the baseline."""
    g = rmat_graph(scale)
    pg = alg.prepare(g, T)
    srcs = _sources(g, queries, seed)
    want = _oracle(g, app, srcs)
    rows = []

    def serve_row(backend, width, arrival, policy, nq=None):
        sub = srcs[:nq] if nq else srcs
        cfg = engine_cfg(T=T, backend=backend)
        fe = Frontend(pg, app=app, cfg=cfg, width=width, policy=policy)
        rep = fe.serve(sub, arrival=arrival, gap=gap, seed=seed)
        # correctness: every streamed query result against the host
        # oracle (the per-lane == solo-run *bit-identity* is pinned by
        # tests/test_serve.py)
        ok = (len(rep.records) == len(sub)
              and all(np.array_equal(r.values, want[r.source])
                      for r in rep.records))
        # amortization: B > 1 must strictly beat sequential rounds
        if width > 1 and len(sub) > 1:
            ok = ok and rep.total_rounds < rep.seq_rounds
        r = rep.row()
        rung = f"B{width}" + ("-cont" if policy == "continuous" else "")
        return {
            "bench": "fig12", "rung": rung, "app": app,
            "arrival": arrival, "backend": backend, "noc": cfg.noc,
            "queries": r["queries"], "rounds": r["rounds"],
            "seq_rounds": r["seq_rounds"], "batches": r["batches"],
            "qps": r["qps"], "gteps": r["gteps"],
            "j_per_query": r["j_per_query"],
            "lat_p50": r["lat_p50"], "lat_p95": r["lat_p95"],
            "lat_max": r["lat_max"], "cycles": r["cycles"],
            "energy_pj": r["energy_pj"], "drops": r["drops"], "ok": ok,
        }

    for backend in backends:
        for width in widths:
            for arrival in arrivals:
                rows.append(serve_row(backend, width, arrival, "static"))
    if continuous:
        rows.append(serve_row(backends[0], max(widths), arrivals[0],
                              "continuous"))
    if pallas_width:
        rows.append(serve_row("pallas", pallas_width, arrivals[0],
                              "static", nq=pallas_width))
    return rows


if __name__ == "__main__":  # PYTHONPATH=src:. python benchmarks/fig12_serving.py [--fast]
    import sys
    fast = "--fast" in sys.argv
    rows = run(scale=8 if fast else 10, T=8 if fast else 16,
               queries=16 if fast else 64,
               widths=(1, 8) if fast else (1, 8, 64),
               arrivals=("burst",) if fast else ("burst", "poisson"),
               pallas_width=0 if fast else 8)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()), flush=True)
