"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
table — single-pod, per the spec; pod2 rows prove the multi-pod compile."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def load_cells(art_dir: str = ART) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(art_dir: str = ART, tag: str = "") -> list[dict]:
    rows = []
    for c in load_cells(art_dir):
        if c["mesh"] != "pod1" or c.get("tag", "") != tag:
            continue
        if c["status"] == "skipped":
            rows.append({"bench": "roofline", "arch": c["arch"],
                         "shape": c["shape"], "status": "skipped",
                         "reason": c["reason"][:40]})
            continue
        if c["status"] != "ok":
            rows.append({"bench": "roofline", "arch": c["arch"],
                         "shape": c["shape"], "status": "ERROR"})
            continue
        r = c["roofline"]
        rows.append({
            "bench": "roofline", "arch": c["arch"], "shape": c["shape"],
            "status": "ok",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"].replace("_s", ""),
            "useful_flops": round(r.get("useful_flop_fraction", 0), 3),
            "roofline_frac": round(r["roofline_fraction"], 4),
        })
    return rows


def multipod_summary(art_dir: str = ART, tag: str = "") -> list[dict]:
    rows = []
    for c in load_cells(art_dir):
        if c["mesh"] != "pod2" or c.get("tag", "") != tag:
            continue
        rows.append({
            "bench": "dryrun-pod2", "arch": c["arch"], "shape": c["shape"],
            "status": c["status"],
            "compile_s": c.get("compile_s"),
            "temp_gb": round((c.get("memory_analysis", {})
                              .get("temp_size_in_bytes") or 0) / 2**30, 2)
            if c["status"] == "ok" else None,
        })
    return rows


def before_after(art_dir: str = ART) -> list[dict]:
    """§Perf: paper-faithful baseline vs beyond-paper optimized, per cell."""
    base = {(c["arch"], c["shape"]): c for c in load_cells(art_dir)
            if c["mesh"] == "pod1" and c.get("tag", "") == ""
            and c["status"] == "ok"}
    opt = {(c["arch"], c["shape"]): c for c in load_cells(art_dir)
           if c["mesh"] == "pod1" and c.get("tag", "") == "opt"
           and c["status"] == "ok"}
    rows = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key]["roofline"], opt[key]["roofline"]
        bb = max(b["compute_s"], b["memory_s"], b["collective_s"])
        ob = max(o["compute_s"], o["memory_s"], o["collective_s"])
        rows.append({
            "bench": "before_after", "arch": key[0], "shape": key[1],
            "rf_base": round(b["roofline_fraction"], 4),
            "rf_opt": round(o["roofline_fraction"], 4),
            "bound_speedup": round(bb / max(ob, 1e-12), 2),
            "dom_base": b["dominant"].replace("_s", ""),
            "dom_opt": o["dominant"].replace("_s", ""),
        })
    return rows
