"""Paper Fig. 5: the optimization ladder, one rung at a time.

Rungs (mapped to our knobs; the paper's interrupt/SRAM rungs are physical
and cannot be re-measured in a functional model — documented):

  tesseract-like : vertex-aligned edges + high-order placement + static
                   scheduling + per-epoch barrier (BSP)
  +data-local    : equal-edge chunking (the paper's Data-Local rung)
  +uniform       : low-order-bit placement (Uniform-distr rung)
  +traffic-aware : queue-occupancy TSU budgets (Traffic-aware rung)
  +barrierless   : async frontier (the final Dalorex-full rung)

Reported per rung: rounds (the time proxy — one round = one grid-wide
task/route/apply pipeline pass), messages, spills, and the work-imbalance
ratio.  The paper's claim validated here: every rung improves (or holds)
the rounds count, and the full ladder is strictly better than the
tesseract-like start.
"""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row)

RUNGS = [
    ("tesseract-like", dict(scheme="high_order", edge_mode="vertex_aligned"),
     dict(policy="static", mode="bsp")),
    ("+data-local", dict(scheme="high_order", edge_mode="equal_edges"),
     dict(policy="static", mode="bsp")),
    ("+uniform-distr", dict(scheme="low_order", edge_mode="equal_edges"),
     dict(policy="static", mode="bsp")),
    ("+traffic-aware", dict(scheme="low_order", edge_mode="equal_edges"),
     dict(policy="traffic", mode="bsp")),
    ("+barrierless", dict(scheme="low_order", edge_mode="equal_edges"),
     dict(policy="traffic", mode="async")),
]

APPS = ("bfs", "sssp", "pagerank", "wcc")


def run(scale: int = 10, T: int = 16, apps=APPS) -> list[dict]:
    g = rmat_graph(scale)
    gs = alg.symmetrize(g)
    root = pick_root(g)
    rows = []
    for name, part_kw, cfg_kw in RUNGS:
        pg = alg.prepare(g, T, **part_kw)
        pgs = alg.prepare(gs, T, **part_kw)
        for app in apps:
            cfg = engine_cfg(**cfg_kw)
            if app == "bfs":
                res = alg.bfs(pg, root, cfg)
            elif app == "sssp":
                res = alg.sssp(pg, root, cfg)
            elif app == "wcc":
                res = alg.wcc(pgs, cfg)
            else:  # pagerank keeps its barrier (as in the paper's Fig. 5)
                cfg = engine_cfg(policy=cfg_kw["policy"], mode="bsp")
                res = alg.pagerank(pg, iters=5, cfg=cfg)
            s = stats_row(res.stats)
            p = perf_cols(res.stats, cfg)
            imb = s["work_max"] * (pg.T if app != "wcc" else pgs.T) \
                / max(s["edges_scanned"], 1)
            rows.append({
                "bench": "fig5", "rung": name, "app": app,
                "rounds": s["rounds"], "msgs": s["msgs_range"]
                + s["msgs_update"], "spills": s["spills_range"]
                + s["spills_update"], "edges": s["edges_scanned"],
                "imbalance": round(imb, 3), "drops": s["drops"],
                "cycles": p["cycles"], "time_model_s": p["time_model_s"],
                "gteps": p["gteps"], "energy_pj": p["energy_pj"],
            })
    return rows
