"""Paper Fig. 7: throughput (edges/round and aggregate memory-touch proxy)
growing with tile count — MBW scales linearly with tiles because every tile
owns private memory; the engine analogue is edges+updates applied per round
across the grid."""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import engine_cfg, pick_root, rmat_graph, stats_row


def run(scale: int = 12, tiles=(4, 8, 16, 32, 64), apps=("bfs", "sssp")
        ) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    rows = []
    for app in apps:
        for T in tiles:
            pg = alg.prepare(g, T)
            res = (alg.bfs if app == "bfs" else alg.sssp)(
                pg, root, engine_cfg(T=T))
            s = stats_row(res.stats)
            # bytes touched: each edge scan reads (dst, val) 8B; each update
            # applies a read-modify-write 8B — the paper's MBW proxy
            bytes_touched = s["edges_scanned"] * 8 + s["updates_applied"] * 8
            rows.append({
                "bench": "fig7", "app": app, "T": T,
                "edges_per_round": round(s["edges_scanned"]
                                         / max(s["rounds"], 1), 1),
                "bytes_per_round": round(bytes_touched
                                         / max(s["rounds"], 1), 1),
                "rounds": s["rounds"],
            })
    return rows
