"""Paper Fig. 7: throughput growing with tile count — MBW scales linearly
with tiles because every tile owns private memory.  The engine analogue
used to be edges+updates per round; with the cycle model (repro.perf) the
rows now report GTEPS (giga traversed edges per modeled second) and the
aggregate memory-touch proxy per modeled time, like the paper's
edges/s curves."""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row)


def run(scale: int = 12, tiles=(4, 8, 16, 32, 64), apps=("bfs", "sssp")
        ) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    rows = []
    for app in apps:
        for T in sorted(tiles):
            pg = alg.prepare(g, T)
            cfg = engine_cfg(T=T)
            res = (alg.bfs if app == "bfs" else alg.sssp)(pg, root, cfg)
            s = stats_row(res.stats)
            p = perf_cols(res.stats, cfg)
            # bytes touched: each edge scan reads (dst, val) 8B; each update
            # applies a read-modify-write 8B — the paper's MBW proxy
            bytes_touched = s["edges_scanned"] * 8 + s["updates_applied"] * 8
            rows.append({
                "bench": "fig7", "app": app, "T": T,
                "edges_per_round": round(s["edges_scanned"]
                                         / max(s["rounds"], 1), 1),
                "cycles": p["cycles"],
                "time_model_s": p["time_model_s"],
                "gteps": p["gteps"],
                "energy_pj": p["energy_pj"],
                "gbytes_per_s": round(bytes_touched
                                      / max(p["time_model_s"], 1e-12)
                                      / 1e9, 3),
                "rounds": s["rounds"],
            })
    return rows
