"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import numpy as np

from repro.core import algorithms as alg
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges


def engine_cfg(T: int = 16, **kw):
    # deliberately tight channel capacities: backpressure (spill/replay)
    # differences between placements/policies must be visible, as in the
    # paper's finite router buffers.  The local update queue must absorb a
    # full T2 burst (no-drop invariant), which grows with the grid size.
    base = dict(f_pop=32, r_pop=32, u_pop=64, max_t2=16,
                cap_route_range=8, cap_route_update=32,
                max_rounds=200_000)
    base.update(kw)
    # size the queues from the engine's own worst-case inflow bounds
    rangeq, burst = EngineConfig(**base).min_caps(T)
    base.setdefault("cap_rangeq", max(512, 1 << (rangeq - 1).bit_length()))
    base.setdefault("cap_updq", max(8192, 1 << (burst - 1).bit_length()))
    return EngineConfig(**base)


def rmat_graph(scale: int, ef: int = 10, seed: int = 0) -> CSRGraph:
    n, src, dst, val = rmat_edges(scale, edge_factor=ef, seed=seed)
    return CSRGraph.from_edges(n, src, dst, val)


def pick_root(g: CSRGraph) -> int:
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


def timed(fn, *args, repeat: int = 1, **kw):
    """(result, best seconds).  First call includes compile; we time the
    post-compile repeats when repeat > 1."""
    result = fn(*args, **kw)
    best = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return result, (best if best is not None else 0.0)


def stats_row(stats, queries=None, qps=None) -> dict:
    """Flatten Stats for CSV-ish rows: scalars as ints (floats for the
    cycle/energy model fields), telemetry arrays (flits_per_link,
    hop_histogram) summarized as max/sum.  The per-channel msgs/spills
    vectors are emitted in full as ``msgs_<i>`` / ``spills_<i>`` — deep
    programs (triangles' 4-channel chain) keep their middle channels —
    plus the legacy first/last-channel scalar keys (``msgs_range`` /
    ``msgs_update``) as views, which alias the same channel for
    single-channel programs.

    Serving rows (fig12 / repro.serve) pass ``queries`` and ``qps``; the
    keys are ADDITIVE — omitted when not given, so the pre-serving
    baseline rows (BENCH_PR3.baseline.json) stay byte-stable.  The
    ``launches`` counter (pallas_call dispatches, PR7) follows the same
    pattern: emitted only when nonzero, so every xla row — the whole
    pre-pallas baseline — stays byte-stable; the per-space counters
    (``hbm_windows`` / ``hbm_edges``, PR8) likewise appear only on runs
    whose edge shard actually streamed from HBM, and the migration
    counters (``migrated_vertices`` / ``migration_cycles`` /
    ``migration_pj``, PR10) only on runs that applied an adaptive
    placement plan."""
    out = {}
    if queries is not None:
        out["queries"] = int(queries)
    if qps is not None:
        out["qps"] = round(float(qps), 1)
    for k in stats._fields:
        if k in ("launches", "hbm_windows", "hbm_edges",
                 "migrated_vertices", "migration_cycles", "migration_pj") \
                and not np.asarray(getattr(stats, k)).any():
            continue  # 0 when the feature is off: omit, keeping the
            #           pre-feature baseline rows byte-stable
        v = np.asarray(getattr(stats, k))
        if v.ndim == 0:
            out[k] = float(v) if np.issubdtype(v.dtype, np.floating) \
                else int(v)
        else:
            if k in ("msgs", "spills"):
                for i in range(v.shape[0]):
                    out[f"{k}_{i}"] = int(v[i])
                out[f"{k}_range"] = int(v[0])
                out[f"{k}_update"] = int(v[-1])
            out[f"{k}_max"] = int(v.max())
            out[f"{k}_sum"] = int(v.sum())
    return out


def perf_cols(stats, cfg: EngineConfig, T: int = None, trace=None) -> dict:
    """Modeled time / throughput / energy columns for a figure row.

    Takes the run's ``cfg`` so overridden `PerfParams` (clock, leak, op
    costs) price the derived columns exactly like the accumulator did.
    ``trace`` (a TraceBuf from a ``cfg.trace`` run) adds the flight
    recorder's ``util_mean`` / ``work_cov`` columns — additive, so
    untraced rows keep their historical shape.
    """
    from repro.perf import derived_metrics
    return derived_metrics(stats, cfg.perf, T, trace=trace)
