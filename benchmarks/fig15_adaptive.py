"""When does adaptive placement pay off? (PR10, repro.place)

The paper's §5 placement study and the ROADMAP's "telemetry-driven
adaptive placement" item meet here: the static schemes (fig8's hier
rungs) are the baseline, and the adaptive rung closes the loop — run the
fig8 workload once with the flight recorder on, feed the observed
per-tile busy cycles to the planner (:mod:`repro.place`), migrate within
the budget, and run the SAME query again on the relabeled partition.

Rungs (all on the hier fabric, uncapped links, ``mode="bsp"``):

* ``static``            — low_order placement: the die-oblivious scatter.
* ``static_dielocal``   — low_order_dielocal: the best *static* scheme
  (fig8's winner); also the adaptive rung's starting partition and its
  correctness twin.
* ``adaptive``          — between-query adaptation: the post-migration
  rerun of the same BFS root, with the one-time migration priced into
  ``cycles`` / ``energy_pj`` (and reported separately in the
  ``migration_*`` columns).  ``ok`` asserts the relabeling contract:
  values bit-identical to ``static_dielocal``'s.
* ``adaptive_epoch``    — epoch-boundary adaptation inside one run:
  :func:`repro.place.adaptive_pagerank` vs the plain pagerank twin
  (``ok`` = values allclose — the acc-fold order is placement-dependent —
  and at least one applied plan).

BSP mode keeps message counts structural (one update per scanned edge
per epoch), so the ``die_flits`` column measures the placement itself
rather than async re-emission noise; ``busy_share_max`` (hottest tile's
share of total busy cycles, from the recorder) is the work-balance axis
— 1/T is perfect balance.  ``benchmarks/smoke.py`` gates the adaptive
rung strictly improving BOTH columns over ``static_dielocal``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import algorithms as alg
from repro.noc.network import make_network
from repro.perf.model import die_crossing_frac, flits_by_class
from repro.place import (adapt_partition, adaptive_pagerank, cfg_tile_die,
                         plan_from_trace, price_migration, score_tiles)
from repro.place.migrate import apply_plan
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row)


def _busy_share_max(trace) -> float:
    busy = score_tiles(trace)
    total = busy.sum()
    return float(busy.max() / total) if total > 0 else 0.0


def _row(rung: str, app: str, res, cfg, T, ndies, net) -> dict:
    s = res.stats
    by_cls = flits_by_class(s, net)
    p = perf_cols(s, cfg, T)
    row = {
        "bench": "fig15", "rung": rung, "app": app,
        "ndies": f"{ndies[0]}x{ndies[1]}",
        "rounds": int(s.rounds),
        "msgs": int(np.asarray(s.msgs).sum()),
        "spills": int(np.asarray(s.spills).sum()),
        "die_frac": round(die_crossing_frac(s), 3),
        "die_flits": by_cls.get("die", 0),
        "busy_share_max": round(_busy_share_max(res.trace), 4),
        "cycles": p["cycles"],
        "energy_pj": p["energy_pj"],
        "util_mean": perf_cols(s, cfg, T, trace=res.trace)["util_mean"],
    }
    mig = stats_row(s)
    for k in ("migrated_vertices", "migration_cycles", "migration_pj"):
        if k in mig:  # additive, like every post-seed Stats column
            row[k] = mig[k]
    return row


def run(scale: int = 10, T: int = 16, ndies=(2, 2),
        budget: int | None = None, trace_rounds: int = 4096) -> list[dict]:
    """The fig15 rows; ``budget`` defaults to V // 8 (a small slice of the
    graph — adaptation must win by moving little, or it isn't winning)."""
    g = rmat_graph(scale)
    root = pick_root(g)
    if budget is None:
        budget = g.num_vertices // 8
    ndies_y, ndies_x = ndies
    base_cfg = engine_cfg(T=T, noc="hier", link_cap=0, mode="bsp",
                          ndies_y=ndies_y, ndies_x=ndies_x, trace=True,
                          trace_rounds=trace_rounds, adapt_budget=budget)
    net = make_network(base_cfg, T)
    rows = []

    # -- static rungs ------------------------------------------------------
    pgs = {
        "static": alg.prepare(g, T, scheme="low_order"),
        "static_dielocal": alg.prepare(g, T, scheme="low_order_dielocal",
                                       dies=ndies),
    }
    results = {}
    for rung, pg in pgs.items():
        results[rung] = alg.bfs(pg, root, base_cfg)
        row = _row(rung, "bfs", results[rung], base_cfg, T, ndies, net)
        row["ok"] = bool(np.array_equal(results[rung].values,
                                        results["static"].values))
        rows.append(row)

    # -- adaptive (between-query): observe -> migrate -> rerun -------------
    pg0 = pgs["static_dielocal"]
    obs = results["static_dielocal"]
    tile_die = cfg_tile_die(base_cfg, T)
    plan = plan_from_trace(pg0, base_cfg, obs.trace)
    pg1 = apply_plan(g, pg0, plan, tile_die=tile_die)
    res = alg.bfs(pg1, root, base_cfg)
    res = dataclasses.replace(
        res, stats=price_migration(res.stats, pg0, plan, T,
                                   params=base_cfg.perf, tile_die=tile_die))
    row = _row("adaptive", "bfs", res, base_cfg, T, ndies, net)
    row["plan_pairs"] = plan.num_pairs
    row["ok"] = bool(np.array_equal(res.values, obs.values))
    rows.append(row)

    # -- adaptive_epoch: migration inside one pagerank run -----------------
    iters = 6
    adapt_cfg = dataclasses.replace(base_cfg, adapt=True, adapt_every=2)
    twin = alg.pagerank(pg0, iters=iters, cfg=base_cfg)
    ares, _, plans = adaptive_pagerank(g, pg0, iters=iters, cfg=adapt_cfg,
                                       params=adapt_cfg.perf)
    row = _row("adaptive_epoch", "pagerank", ares, adapt_cfg, T, ndies, net)
    row["plan_pairs"] = sum(p.num_pairs for p in plans)
    row["ok"] = bool(np.allclose(ares.values, twin.values,
                                 rtol=1e-6, atol=1e-12)
                     and len(plans) > 0)
    rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))
