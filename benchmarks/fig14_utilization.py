"""Utilization-over-time figure from the flight recorder (PR9).

Beyond the run-level ``Stats`` every earlier figure aggregates, the
recorder (``repro.trace``, DESIGN.md "Tracing & observability") keeps the
per-round series — so this bench plots WHERE the cycles go over a
traversal's lifetime: per-round mean tile utilization (busy cycles over
the round's critical-path envelope) and the work-imbalance CoV, swept
across NoC fabric x placement x TSU policy.

Per combo the workload runs twice, trace off then trace on, and the ``ok``
column asserts the recorder's non-perturbation contract on the live
configs: values AND every ``Stats`` field bit-identical, and the trace's
cycle timeline reconciling bitwise with ``Stats.cycles`` (the trace-off
run is the committed-baseline behavior; the trace must be a pure read).

Row columns: identity (noc / placement / policy), the usual counters and
modeled cycles/energy, the recorder's additive ``util_mean`` /
``work_cov``, per-phase utilization (ramp / steady / drain), and
``util_series`` — the per-round utilization bucket-averaged to at most
``series_points`` points (the figure's y values; ``series_rounds`` rounds
per bucket).

Rows feed ``benchmarks/smoke.py`` (BENCH json + the standalone
``BENCH_FIG14.json`` artifact) at T=4 / scale=6 / 2x1 dies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import algorithms as alg
from repro.trace.export import (reconcile_cycles, summarize, trace_arrays,
                                utilization)
from benchmarks.common import engine_cfg, perf_cols, pick_root, rmat_graph

# (noc, placement, policy): mesh vs the multi-die hier fabric, balanced vs
# hub-concentrating vs die-local placement, traffic-aware vs static TSU.
COMBOS = (
    ("mesh", "low_order", "traffic"),
    ("mesh", "high_order", "traffic"),
    ("mesh", "low_order", "static"),
    ("hier", "low_order", "traffic"),
    ("hier", "low_order_dielocal", "traffic"),
    ("hier", "low_order_dielocal", "static"),
)


def _stats_identical(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def _series(util: np.ndarray, points: int) -> tuple[list, int]:
    """Bucket-average a per-round series to at most ``points`` values."""
    n = len(util)
    if n == 0:
        return [], 0
    per = max(1, -(-n // points))  # ceil
    vals = [round(float(util[i:i + per].mean()), 4)
            for i in range(0, n, per)]
    return vals, per


def run(scale: int = 10, T: int = 16, ndies=(2, 2), combos=COMBOS,
        trace_rounds: int = 4096, series_points: int = 24) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    rows = []
    for noc, placement, policy in combos:
        hier = noc == "hier"
        dies = ndies if placement.endswith("_dielocal") else None
        pg = alg.prepare(g, T, scheme=placement, dies=dies)
        cfg0 = engine_cfg(T=T, noc=noc, policy=policy,
                          ndies_y=ndies[0] if hier else 1,
                          ndies_x=ndies[1] if hier else 1)
        cfg1 = dataclasses.replace(cfg0, trace=True,
                                   trace_rounds=trace_rounds)
        base = alg.bfs(pg, root, cfg0)       # the untraced (baseline) run
        res = alg.bfs(pg, root, cfg1)        # same run, recorder on
        rec = reconcile_cycles(res.trace,
                               float(np.asarray(res.stats.cycles)))
        ok = (bool(np.array_equal(base.values, res.values))
              and _stats_identical(base.stats, res.stats)
              and rec["exact"])
        s = res.stats
        p = perf_cols(s, cfg1, T, trace=res.trace)
        summ = summarize(res.trace)
        util = utilization(trace_arrays(res.trace))
        series, per = _series(util, series_points)
        row = {
            "bench": "fig14", "app": "bfs", "noc": noc,
            "placement": placement, "policy": policy,
            "ndies": f"{ndies[0]}x{ndies[1]}" if hier else "1x1",
            "rounds": int(s.rounds),
            "msgs": int(np.asarray(s.msgs).sum()),
            "spills": int(np.asarray(s.spills).sum()),
            "drops": int(s.drops),
            "cycles": p["cycles"], "energy_pj": p["energy_pj"],
            "gteps": p["gteps"],
            "util_mean": p["util_mean"], "work_cov": p["work_cov"],
            "util_min": round(summ["util_min"], 4),
            "util_max": round(summ["util_max"], 4),
            "crit_tile_mode": summ["crit_tile_mode"],
            "util_series": series, "series_rounds": per,
            "ok": ok,
        }
        for ph in summ["phases"]:
            row[f"util_{ph['phase']}"] = round(ph["util_mean"], 4)
            row[f"cov_{ph['phase']}"] = round(ph["work_cov"], 4)
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))
