"""Kernel-launch overhead microbench: what does one ``pallas_call`` cost?

PR7's fused round leg exists because per-launch dispatch overhead — not
tile work — dominates a round once the kernels themselves are small (the
ALPHA-PIM observation: on real silicon, per-operation launch/sync cost is
what separates modeled from measured GTEPS).  This bench prices that
overhead directly, so fig11's ``launches_per_round`` column converts to
time:

* ``bump_chain_*`` — a chain of N trivial (+1) kernels launched one
  ``pallas_call`` each, vs the same N adds inside ONE fused launch
  (``fused_leg_call``).  The wall-clock difference over N-1 saved
  launches is the marginal per-launch overhead (``us_per_launch_saved``).
* ``leg_*`` — a synthetic classic channel leg (frontier-pop -> FIFO turn
  -> segment-gather -> scatter-fold) on representative shapes, as PR4's
  four standalone kernel launches vs PR7's single fused launch
  (``leg_delta_us`` = the per-leg fusion win).

Launch counts per variant are *measured* (the ``repro.kernels.engine.
launches`` tally around an abstract trace), not hardcoded — the fused
variants must count exactly 1.  Wall-clock columns are machine-dependent
(and, under ``interpret=True`` on CPU, interpreter-taxed); the
deterministic ``launches`` column is what the smoke baseline keeps.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.engine import (edge_scan_gather, fifo_turn, fold_scatter,
                                  frontier_pop, frontier_take,
                                  fused_leg_call, queue_push_pop, record,
                                  scatter_body, segment_gather, tally)

_K_MAX = 16     # frontier pop bound / queue pop budget
_MAX_T2 = 8     # edge-scan bound


def _bump_kernel(x_ref, y_ref):
    y_ref[...] = x_ref[...] + 1


def _bump(x, interpret=True):
    record()  # raw pallas_call: tally it like the library wrappers do
    return pl.pallas_call(
        _bump_kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret)(x)


def _chain_unfused(n, interpret):
    def fn(x):
        for _ in range(n):
            x = _bump(x, interpret)
        return x
    return fn


def _chain_fused(n, interpret):
    def body(x):
        for _ in range(n):
            x = x + 1
        return x

    def fn(x):
        return fused_leg_call(body, x, interpret=interpret)
    return fn


def _leg_inputs(v_chunk, e_chunk, cap, seed=0):
    """Representative per-tile leg operands (classic program shapes)."""
    rng = np.random.default_rng(seed)
    return dict(
        mask=jnp.asarray(rng.random(v_chunk) < 0.3),
        budget=jnp.int32(_K_MAX),
        qdata=jnp.asarray(rng.integers(0, e_chunk, (cap, 3)), jnp.int32),
        qcount=jnp.int32(cap // 2),
        rows=jnp.asarray(rng.integers(0, e_chunk, (_K_MAX, 3)), jnp.int32),
        rvalid=jnp.asarray(rng.random(_K_MAX) < 0.8),
        edge_dst=jnp.asarray(
            rng.integers(-1, v_chunk, e_chunk), jnp.int32),
        edge_val=jnp.asarray(rng.random(e_chunk), jnp.float32),
        target=jnp.asarray(rng.random(v_chunk), jnp.float32),
    )


def _leg(fused, interpret):
    """The classic leg chain on one tile: pop -> turn -> gather -> fold.
    ``fused=False`` launches PR4's four standalone kernels; ``fused=True``
    composes the pure bodies and will be run inside ONE fused_leg_call."""

    def chain(mask, budget, qdata, qcount, rows, rvalid, edge_dst,
              edge_val, target):
        if fused:
            vidx, vvalid, mask2 = frontier_take(mask, budget, _K_MAX)
            taken, tvalid, qdata2, qcount2, drops = fifo_turn(
                qdata, qcount, rows, rvalid, budget, _K_MAX)
            nb, w, jv = segment_gather(
                edge_dst, edge_val, taken[:, 0], taken[:, 1], tvalid,
                _MAX_T2)
            lidx = jnp.where(jv, nb % target.shape[0],
                             target.shape[0]).reshape(-1)
            out = scatter_body(target, lidx, w.reshape(-1), jv.reshape(-1),
                               "min")
        else:
            vidx, vvalid, mask2 = frontier_pop(mask, budget, _K_MAX,
                                               interpret=interpret)
            taken, tvalid, qdata2, qcount2, drops = queue_push_pop(
                qdata, qcount, rows, rvalid, budget, _K_MAX,
                interpret=interpret)
            nb, w, jv = edge_scan_gather(
                edge_dst, edge_val, taken[:, 0], taken[:, 1], tvalid,
                _MAX_T2, interpret=interpret)
            lidx = jnp.where(jv, nb % target.shape[0],
                             target.shape[0]).reshape(-1)
            out = fold_scatter(target, lidx, w.reshape(-1), jv.reshape(-1),
                               op="min", interpret=interpret)
        return vidx, vvalid, mask2, qdata2, qcount2, drops, out

    if not fused:
        return chain

    def one_launch(*args):
        return fused_leg_call(chain, *args, interpret=interpret)
    return one_launch


def _count_launches(fn, *args) -> int:
    """Measured launch count: records taken while tracing fn abstractly."""
    with tally() as t:
        jax.eval_shape(fn, *args)
    return t.n


def _best_wall(fn, args, repeat):
    jitted = jax.jit(fn)
    jax.block_until_ready(jitted(*args))  # compile
    best = None
    for _ in range(max(repeat, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def run(n_chain: int = 32, size: int = 1024, repeat: int = 3,
        interpret: bool = True, timing: bool = True) -> list[dict]:
    """``timing=False`` drops the machine-dependent wall-clock columns
    (what smoke.py commits to the baseline; the measured ``launches``
    column stays)."""
    rows = []

    # --- bump chain: N launches vs 1 ---------------------------------
    x = jnp.zeros((size,), jnp.int32)
    un = _chain_unfused(n_chain, interpret)
    fu = _chain_fused(n_chain, interpret)
    l_un = _count_launches(un, x)
    l_fu = _count_launches(fu, x)
    w_un = _best_wall(un, (x,), repeat) if timing else None
    w_fu = _best_wall(fu, (x,), repeat) if timing else None
    row_un = {"bench": "kern_micro", "kernel": "bump_chain_unfused",
              "launches": l_un, "ok": l_un == n_chain}
    row_fu = {"bench": "kern_micro", "kernel": "bump_chain_fused",
              "launches": l_fu, "ok": l_fu == 1}
    if timing:
        row_un["wall_s"] = round(w_un, 5)
        row_fu["wall_s"] = round(w_fu, 5)
        row_fu["us_per_launch_saved"] = round(
            1e6 * (w_un - w_fu) / max(l_un - l_fu, 1), 2)
    rows += [row_un, row_fu]

    # --- one classic channel leg: 4 launches vs 1 ---------------------
    ins = _leg_inputs(v_chunk=size, e_chunk=4 * size, cap=4 * _K_MAX)
    args = tuple(ins.values())
    leg4 = _leg(fused=False, interpret=interpret)
    leg1 = _leg(fused=True, interpret=interpret)
    l4 = _count_launches(leg4, *args)
    l1 = _count_launches(leg1, *args)
    w4 = _best_wall(leg4, args, repeat) if timing else None
    w1 = _best_wall(leg1, args, repeat) if timing else None
    # the fused leg must be bit-identical to the four-kernel chain
    o4 = jax.jit(leg4)(*args)
    o1 = jax.jit(leg1)(*args)
    same = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
               for a, b in zip(jax.tree.leaves(o4), jax.tree.leaves(o1)))
    row4 = {"bench": "kern_micro", "kernel": "leg_unfused",
            "launches": l4, "ok": l4 == 4 and same}
    row1 = {"bench": "kern_micro", "kernel": "leg_fused",
            "launches": l1, "ok": l1 == 1 and same}
    if timing:
        row4["wall_s"] = round(w4, 5)
        row1["wall_s"] = round(w1, 5)
        row1["leg_delta_us"] = round(1e6 * (w4 - w1), 2)
    rows += [row4, row1]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))
