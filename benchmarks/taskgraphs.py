"""New-workload benchmark: task graphs beyond the fixed T1/T2/T3 pipeline.

The generic task-program executor (repro.core.program) runs anything with
an owner function and a handler chain.  This benchmark exercises the two
workloads whose task-graph *shapes* the old engine could not express:

* k-core peeling — the classic 3-task shape with a threshold fold whose
  decrements re-arm the frontier (rows per k, async vs BSP);
* 2-hop triangle counting — a 4-channel chain (range -> wedge -> second
  range at the neighbor's owner -> intersection-count fold) with
  per-channel message telemetry.

Every row is validated against the sequential numpy references.
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg
from repro.core import reference as ref
from benchmarks.common import engine_cfg, perf_cols, rmat_graph, stats_row


def run(scale: int = 10, T: int = 16, ks=(2, 3, 4)) -> list[dict]:
    g = rmat_graph(scale)
    gs = alg.symmetrize(g)
    rows = []

    pgs = alg.prepare(gs, T)
    for k in ks:
        want = ref.kcore_ref(gs, k)
        for mode in ("async", "bsp"):
            cfg = engine_cfg(T=T, mode=mode)
            res = alg.kcore(pgs, k, cfg)
            s = stats_row(res.stats)
            p = perf_cols(res.stats, cfg)
            rows.append({
                "bench": "taskgraph", "app": f"kcore{k}", "mode": mode,
                "rounds": s["rounds"], "epochs": s["epochs"],
                "members": int(res.values.sum()),
                "msgs": s["msgs_sum"], "spills": s["spills_sum"],
                "edges": s["edges_scanned"], "drops": s["drops"],
                "cycles": p["cycles"], "energy_pj": p["energy_pj"],
                "gteps": p["gteps"],
                "ok": bool((res.values == want).all()),
            })

    pgt = alg.prepare_triangles(gs, T)
    want = ref.triangles_ref(gs, key=pgt.place)
    for noc in ("ideal", "mesh"):
        cfg = engine_cfg(T=T, noc=noc)
        res = alg.triangles(pgt, cfg)
        s = stats_row(res.stats)
        p = perf_cols(res.stats, cfg)
        row = {
            "bench": "taskgraph", "app": "triangles", "noc": noc,
            "rounds": s["rounds"], "triangles": int(res.values.sum()),
            "msgs": s["msgs_sum"], "spills": s["spills_sum"],
            "edges": s["edges_scanned"], "drops": s["drops"],
            "cycles": p["cycles"], "energy_pj": p["energy_pj"],
            "gteps": p["gteps"],
            "ok": bool((res.values == want).all()),
        }
        # per-channel traffic: the 4-channel chain's signature
        for i, name in enumerate(("range", "wedge", "range2", "close")):
            row[f"msgs_{name}"] = int(np.asarray(res.stats.msgs)[i])
        rows.append(row)
    return rows
