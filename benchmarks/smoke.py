"""Benchmark smoke runner for CI: tiny-scale figure drivers so benchmark
code cannot rot unnoticed.

Runs the fig5 optimization ladder, the task-graph workloads, the fig8
hierarchy column (mesh vs torus vs multi-die hier + die-local placement),
the fig11 backend bench (xla vs pallas-nofuse vs fused pallas, ideal +
the multi-die hier corner — the CI proof that ``backend="pallas"`` rows
exist, match bit-for-bit and run one launch per channel leg, the
``launches_per_round`` column), the kern_micro launch-overhead rows
(measured launch counts; fused variants must report exactly 1), and the fig12
serving bench (batched query lanes: static + continuous batching +
a pallas-backend batch, queries/sec rows), the fig13 memory-space
ladder (VMEM-resident vs HBM-streamed edge shards: bit-identical values,
per-space pricing, the config-time rejection of an over-budget all-VMEM
layout), and the fig14 utilization rows (flight-recorder traces across
noc x placement x policy; every row asserts trace-on is bit-identical to
the untraced run and carries ``util_mean > 0`` and a finite
``work_cov``), and the fig15 adaptive-placement rows (telemetry-driven
migration: the adaptive rung must STRICTLY beat the best static
die-local placement on both die-crossing flits and hottest-tile busy
share, with the relabeling contract asserted per row) at T=4 / scale=6,
asserts the no-drop invariant and the reference checks on every row, and
writes the
rows — cycle/energy model columns included — as ``BENCH_PR3.json``; the
fig11 / fig12 / fig13 / fig14 / fig15 rows are additionally written
standalone as
``BENCH_FIG11.json`` / ... / ``BENCH_FIG15.json``, plus one example
flight-recorder trace (``smoke.perfetto.json``, loadable at
ui.perfetto.dev) — all uploaded as CI artifacts.

The per-space Stats columns (``hbm_windows`` / ``hbm_edges``) follow the
additive-keys convention: they may appear ONLY on ``space == "hbm"``
rows, so every pre-memspace baseline row stays byte-stable — asserted
here, not just promised.

If the committed baseline (``benchmarks/BENCH_PR3.baseline.json``) exists,
every row is matched against it by its identity columns and the run FAILS
if any row's ``rounds`` regressed (grew) vs the baseline — the engine is
deterministic at fixed seeds, so a regression here is a real scheduling /
backpressure change, not noise.

  PYTHONPATH=src python benchmarks/smoke.py [--out BENCH_PR3.json]
                                            [--baseline <json>|none]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "BENCH_PR3.baseline.json")

# Columns that identify a row (everything string-valued is identity; these
# are listed explicitly so a new string column cannot silently split keys).
ID_COLS = ("bench", "rung", "app", "mode", "noc", "backend", "placement",
           "ndies", "arrival", "kernel", "space", "policy")


def row_key(row: dict) -> tuple:
    return tuple((c, row[c]) for c in ID_COLS if c in row)


def check_baseline(rows, baseline_path: str) -> list[str]:
    """Compare rounds per row against the committed baseline; returns a
    list of human-readable regressions (empty = pass).  Rows or baselines
    missing on either side are reported too — the baseline must be
    regenerated deliberately, not drift."""
    with open(baseline_path) as f:
        base = {row_key(r): r for r in json.load(f)}
    cur = {row_key(r): r for r in rows}
    problems = []
    for k, r in cur.items():
        b = base.get(k)
        if b is None:
            problems.append(f"row {dict(k)} missing from baseline "
                            f"(regenerate BENCH_PR3.baseline.json)")
        elif r.get("rounds", 0) > b.get("rounds", 0):
            problems.append(
                f"rounds regression {dict(k)}: "
                f"{b.get('rounds')} -> {r.get('rounds')}")
    for k in base:
        if k not in cur:
            problems.append(f"baseline row {dict(k)} no longer produced")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR3.json")
    ap.add_argument("--fig11-out", default="BENCH_FIG11.json",
                    help="standalone copy of the fig11 backend rows; "
                         "'none' to skip")
    ap.add_argument("--fig12-out", default="BENCH_FIG12.json",
                    help="standalone copy of the fig12 serving rows; "
                         "'none' to skip")
    ap.add_argument("--fig13-out", default="BENCH_FIG13.json",
                    help="standalone copy of the fig13 memory-space rows; "
                         "'none' to skip")
    ap.add_argument("--fig14-out", default="BENCH_FIG14.json",
                    help="standalone copy of the fig14 utilization rows; "
                         "'none' to skip")
    ap.add_argument("--fig15-out", default="BENCH_FIG15.json",
                    help="standalone copy of the fig15 adaptive-placement "
                         "rows; 'none' to skip")
    ap.add_argument("--perfetto-out", default="smoke.perfetto.json",
                    help="example flight-recorder Perfetto export "
                         "(CI artifact); 'none' to skip")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline json to diff rounds against; 'none' "
                         "to skip")
    ap.add_argument("--scale", type=int, default=6)
    ap.add_argument("--tiles", type=int, default=4)
    args = ap.parse_args()

    t0 = time.time()
    from benchmarks import (fig5_ablation, fig8_noc, fig11_backend,
                            fig12_serving, fig13_memspace,
                            fig14_utilization, fig15_adaptive, kern_micro,
                            taskgraphs)

    rows = fig5_ablation.run(scale=args.scale, T=args.tiles)
    rows += taskgraphs.run(scale=args.scale, T=args.tiles, ks=(2, 3))
    # the fig8 hierarchy column (mesh vs torus vs hier + die-local
    # placement) — T=4 becomes a 2x2 grid of 1x2-tile dies
    rows += fig8_noc.run_hier(scale=args.scale, T=args.tiles, ndies=(2, 1))
    # timing=False + repeat=0: one engine run per row — the wall-clock is
    # discarded anyway, and the baseline-checked artifact stays
    # machine-independent
    fig11 = fig11_backend.run(scale=args.scale, T=args.tiles,
                              apps=("bfs", "spmv", "triangles"),
                              timing=False, repeat=0)
    # the multi-die corner of the backend bench: fused single-launch legs
    # must stay bit-identical to xla under the hier NoC too
    fig11 += fig11_backend.run(scale=args.scale, T=args.tiles,
                               apps=("bfs",), nocs=("hier",),
                               timing=False, repeat=0)
    rows += fig11
    # launch-overhead microbench: deterministic (measured) launch counts
    # only — the fused variants must report exactly 1 pallas_call
    rows += kern_micro.run(n_chain=8, size=256, timing=False)
    # the fig12 serving rows: batched query lanes (static + continuous +
    # one pallas-backend batch), queries/sec gated like everything else
    fig12 = fig12_serving.run(scale=args.scale, T=args.tiles, queries=12,
                              widths=(1, 4), arrivals=("burst", "poisson"),
                              gap=2000.0, continuous=True, pallas_width=3)
    rows += fig12
    # the fig13 memory-space ladder: VMEM-resident vs HBM-streamed edge
    # shards (includes the internal assertion that an over-budget all-VMEM
    # config REJECTS at Program.validate time while hbm runs it)
    fig13 = fig13_memspace.run(scale=args.scale, T=args.tiles,
                               apps=("bfs", "spmv"))
    rows += fig13
    # the fig14 utilization rows: flight-recorder traces across
    # noc x placement x policy — each row internally asserts trace-on is
    # bit-identical to the untraced run (the `ok` column)
    fig14 = fig14_utilization.run(scale=args.scale, T=args.tiles,
                                  ndies=(2, 1))
    rows += fig14
    # the fig15 adaptive-placement rows: static rungs -> observe -> migrate
    # -> rerun, with the relabeling contract asserted per row (`ok`) and
    # the one-time migration priced into cycles/energy
    fig15 = fig15_adaptive.run(scale=args.scale, T=args.tiles,
                               ndies=(2, 1))
    rows += fig15

    bad = []
    if not any(r.get("backend") == "pallas" for r in rows):
        bad.append("smoke must emit at least one backend=pallas row")
    if not any(r.get("bench") == "fig12" and r.get("qps", 0) > 0
               for r in rows):
        bad.append("smoke must emit fig12 serving rows with qps > 0")
    bad += [r for r in rows if r.get("drops", 0) != 0]
    bad += [r for r in rows if r.get("ok") is False]
    bad += [r for r in rows  # missing perf columns must fail, not pass
            if r.get("bench") != "kern_micro"  # no engine => no perf cols
            and (r.get("cycles", 0) <= 0 or r.get("energy_pj", 0) <= 0)]
    if not any(r.get("bench") == "fig11" and r.get("backend") == "pallas"
               and r.get("launches_per_round", 0) > 0 for r in rows):
        bad.append("fig11 pallas rows must carry launches_per_round > 0")
    if not any(r.get("bench") == "fig13" and r.get("space") == "hbm"
               and r.get("hbm_windows", 0) > 0 and r.get("ok") is True
               for r in rows):
        bad.append("fig13 must emit an ok space=hbm row with "
                   "hbm_windows > 0")
    # every traced fig14 row must record real utilization (a 0 means the
    # recorder captured nothing — the ring/exporter wiring broke) AND a
    # finite work-imbalance CoV: `not (x >= 0)` catches a NaN (every
    # comparison with NaN is False) as well as a missing column, so a
    # silently-NaN covariance fails CI instead of serializing as null
    bad += [r for r in rows
            if r.get("bench") == "fig14"
            and (r.get("util_mean", 0) <= 0
                 or not (r.get("work_cov", -1.0) >= 0))]
    if not any(r.get("bench") == "fig14" for r in rows):
        bad.append("smoke must emit fig14 utilization rows")
    # the fig15 gate: adaptation must PAY — the adaptive rung strictly
    # reduces BOTH die-crossing flits and the hottest tile's busy-cycle
    # share vs the best static die-local placement (its starting point)
    f15 = {r.get("rung"): r for r in rows if r.get("bench") == "fig15"}
    if "adaptive" not in f15 or "static_dielocal" not in f15:
        bad.append("smoke must emit fig15 adaptive + static_dielocal rows")
    elif not (f15["adaptive"]["die_flits"]
              < f15["static_dielocal"]["die_flits"]
              and f15["adaptive"]["busy_share_max"]
              < f15["static_dielocal"]["busy_share_max"]):
        bad.append(
            "fig15 adaptive must strictly beat static_dielocal on "
            "die_flits AND busy_share_max: "
            f"{f15['adaptive']} vs {f15['static_dielocal']}")
    # additive-keys stability: the recorder's columns may appear ONLY on
    # traced (fig14 / fig15) rows — a leak onto any other row would
    # perturb the committed pre-trace baseline rows byte-for-byte
    bad += [r for r in rows
            if r.get("bench") not in ("fig14", "fig15")
            and ("util_mean" in r or "work_cov" in r)]
    # additive-keys stability: the migration counters may appear ONLY on
    # fig15 rows whose run actually migrated (the adaptive rungs)
    bad += [r for r in rows
            if not (r.get("bench") == "fig15"
                    and str(r.get("rung", "")).startswith("adaptive"))
            and ("migrated_vertices" in r or "migration_cycles" in r
                 or "migration_pj" in r)]
    # additive-keys stability: the per-space counters may appear ONLY on
    # hbm rows — a leak onto any other row would perturb the committed
    # pre-memspace baseline rows byte-for-byte
    bad += [r for r in rows
            if r.get("space", "vmem") != "hbm"
            and ("hbm_windows" in r or "hbm_edges" in r)]
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if args.fig11_out != "none":
        with open(args.fig11_out, "w") as f:
            json.dump(fig11, f, indent=1)
    if args.fig12_out != "none":
        with open(args.fig12_out, "w") as f:
            json.dump(fig12, f, indent=1)
    if args.fig13_out != "none":
        with open(args.fig13_out, "w") as f:
            json.dump(fig13, f, indent=1)
    if args.fig14_out != "none":
        with open(args.fig14_out, "w") as f:
            json.dump(fig14, f, indent=1)
    if args.fig15_out != "none":
        with open(args.fig15_out, "w") as f:
            json.dump(fig15, f, indent=1)
    if args.perfetto_out != "none":
        # one loadable example trace (ui.perfetto.dev) as a CI artifact
        import dataclasses as _dc

        import numpy as _np

        from benchmarks.common import engine_cfg as _ecfg
        from benchmarks.common import pick_root as _root
        from benchmarks.common import rmat_graph as _rmat
        from repro.core import algorithms as _alg
        from repro.trace import write_perfetto

        _g = _rmat(args.scale)
        _pg = _alg.prepare(_g, args.tiles)
        _cfg = _dc.replace(_ecfg(T=args.tiles, noc="mesh"), trace=True,
                           trace_rounds=4096)
        _res = _alg.bfs(_pg, _root(_g), _cfg)
        _doc = write_perfetto(_res.trace, args.perfetto_out,
                              meta={"bench": "smoke", "app": "bfs",
                                    "noc": "mesh", "scale": args.scale,
                                    "tiles": args.tiles})
        print(f"wrote {args.perfetto_out}: "
              f"{len(_doc['traceEvents'])} events")
    print(f"wrote {len(rows)} rows to {args.out} in {time.time()-t0:.1f}s")
    if bad:
        print(f"FAILED rows: {bad}")
        return 1
    if args.baseline != "none":
        if not os.path.exists(args.baseline):
            # a missing baseline must fail loudly, not silently skip the
            # regression gate this job advertises ('none' opts out)
            print(f"BASELINE MISSING: {args.baseline}")
            return 1
        problems = check_baseline(rows, args.baseline)
        if problems:
            print("BASELINE REGRESSIONS:")
            for p in problems:
                print(f"  {p}")
            return 1
        print(f"baseline check OK vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
