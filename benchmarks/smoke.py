"""Benchmark smoke runner for CI: tiny-scale figure drivers so benchmark
code cannot rot unnoticed.

Runs the fig5 optimization ladder plus the new task-graph workloads at
T=4 / scale=6, asserts the no-drop invariant and the reference checks on
every row, and writes the rows as JSON (uploaded as a CI artifact).

  PYTHONPATH=src python benchmarks/smoke.py [--out bench-smoke.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench-smoke.json")
    ap.add_argument("--scale", type=int, default=6)
    ap.add_argument("--tiles", type=int, default=4)
    args = ap.parse_args()

    t0 = time.time()
    from benchmarks import fig5_ablation, taskgraphs

    rows = fig5_ablation.run(scale=args.scale, T=args.tiles)
    rows += taskgraphs.run(scale=args.scale, T=args.tiles, ks=(2, 3))

    bad = [r for r in rows if r.get("drops", 0) != 0]
    bad += [r for r in rows if r.get("ok") is False]
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows to {args.out} in {time.time()-t0:.1f}s")
    if bad:
        print(f"FAILED rows: {bad}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
