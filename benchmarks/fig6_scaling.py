"""Paper Fig. 6: strong scaling of BFS over grid sizes.

The paper's claim: near-linear scaling until ~1k vertices/tile, where tiles
starve for work.  Since the cycle model (repro.perf) landed, time is no
longer a rounds proxy: each row reports modeled cycles (per-round critical
path: slowest tile + busiest link), ``time_model_s``, and GTEPS — the
strong-scaling knee must appear in *modeled time*, with fixed per-round
budgets the rounds count alone understates large-grid overheads.

``tiles`` is sorted ascending before use: ``speedup_vs_linear`` normalizes
against the smallest grid, and an unsorted/descending argument used to
silently produce wrong speedups (regression-tested in tests/test_perf.py).
"""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row)


def run(scale: int = 12, tiles=(4, 8, 16, 32, 64)) -> list[dict]:
    tiles = tuple(sorted(tiles))
    assert len(set(tiles)) == len(tiles), f"duplicate tile counts: {tiles}"
    g = rmat_graph(scale)
    root = pick_root(g)
    rows = []
    base_time = None
    for T in tiles:
        pg = alg.prepare(g, T)
        cfg = engine_cfg(T=T)
        res = alg.bfs(pg, root, cfg)
        s = stats_row(res.stats)
        p = perf_cols(res.stats, cfg)
        if base_time is None:
            base_time = p["time_model_s"] * tiles[0]
        rows.append({
            "bench": "fig6", "T": T,
            "vertices_per_tile": g.num_vertices // T,
            "rounds": s["rounds"],
            "cycles": p["cycles"],
            "time_model_s": p["time_model_s"],
            "gteps": p["gteps"],
            "energy_pj": p["energy_pj"],
            "speedup_vs_linear": round(
                base_time / (p["time_model_s"] * T), 3),
            "edges": s["edges_scanned"],
        })
    return rows
