"""Paper Fig. 6: strong scaling of BFS over grid sizes.

The paper's claim: near-linear scaling until ~1k vertices/tile, where tiles
starve for work.  Our time proxy is rounds x per-round critical path; with
fixed per-round budgets, rounds should drop ~linearly with T until the
starvation knee.
"""
from __future__ import annotations

from repro.core import algorithms as alg
from benchmarks.common import engine_cfg, pick_root, rmat_graph, stats_row


def run(scale: int = 12, tiles=(4, 8, 16, 32, 64)) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    rows = []
    base_rounds = None
    for T in tiles:
        pg = alg.prepare(g, T)
        res = alg.bfs(pg, root, engine_cfg(T=T))
        s = stats_row(res.stats)
        if base_rounds is None:
            base_rounds = s["rounds"] * tiles[0]
        rows.append({
            "bench": "fig6", "T": T,
            "vertices_per_tile": g.num_vertices // T,
            "rounds": s["rounds"],
            "speedup_vs_linear": round(
                base_rounds / (s["rounds"] * T), 3),
            "edges": s["edges_scanned"],
        })
    return rows
