"""Paper Fig. 8/9: NoC traffic balance — placements AND fabric topologies.

The mesh-center hotspot in Fig. 9 is *caused* by skewed per-destination
traffic; the torus/ruche rungs fix the fabric, uniform placement fixes the
source.  Two measurement families:

* placement rows (`fig8*`): per-destination message histogram under
  low-order vs high-order placement (max/mean = endpoint contention; the
  paper's heatmap in numbers), plus a dynamic BFS confirmation.
* topology rows (`fig8-topo*`): the physical wiring is now re-measured
  functionally via the pluggable :mod:`repro.noc` subsystem — BFS runs
  over mesh / torus / ruche backends with dimension-ordered routing, and
  the per-link telemetry exposes the mesh-center hotspot directly
  (``max_link_occupancy``, interior-vs-boundary column load) and how
  torus wraparound / ruche express channels flatten it (paper Fig. 9).
  An earlier revision claimed torus-vs-mesh "cannot be re-measured
  functionally"; that held only while the fabric was a single ideal
  all_to_all — see DESIGN.md ("NoC subsystem").
* hierarchy rows (`fig8-hier`, :func:`run_hier`): mesh vs torus vs the
  multi-die `hier` backend at matched tile counts — die-crossing
  fraction, DIE-class express traffic, and the die-local placement rung
  that keeps partitions die-resident (DESIGN.md "Hierarchical NoC").
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg
from repro.noc import (LOCAL_BWD, LOCAL_FWD, N_CHANNELS, grid_shape,
                       make_network)
from repro.perf import die_crossing_frac, flits_by_class
from benchmarks.common import engine_cfg, perf_cols, pick_root, rmat_graph


def _sort_by_degree(g):
    """Adversarial relabeling the paper calls out: vertices sorted by
    degree (hubs get consecutive ids).  Low-order placement must stay
    balanced; high-order concentrates every hub on tile 0."""
    from repro.core.graph import CSRGraph
    deg = g.ptr[1:] - g.ptr[:-1]
    order = np.argsort(-deg)            # new position -> old id
    relabel = np.empty_like(order)
    relabel[order] = np.arange(len(order))
    src = np.repeat(np.arange(g.num_vertices), deg)
    return CSRGraph.from_edges(g.num_vertices, relabel[src],
                               relabel[g.dst], g.val, dedup=False)


def _static_rows(g, T, tag):
    """Per-placement endpoint/work balance, incl. the paper's degree-aware
    rung: ``degree_interleave`` deals hubs round-robin, so its ``work_max``
    balance beats ``low_order``/``high_order`` even on degree-sorted ids."""
    rows = []
    for scheme in ("low_order", "high_order", "degree_interleave"):
        pg = alg.prepare(g, T, scheme=scheme)
        deg = np.asarray(pg.deg).astype(np.int64)
        dst = np.asarray(pg.edge_dst).reshape(pg.T, -1)
        # traffic each tile RECEIVES: updates to its owned vertices
        owners = np.where(dst >= 0, dst // pg.v_chunk, -1)
        recv = np.bincount(owners[owners >= 0].ravel(), minlength=pg.T)
        work = deg.reshape(pg.T, -1).sum(1)
        rows.append({
            "bench": f"fig8{tag}", "placement": scheme,
            "recv_max_over_mean": round(recv.max() / max(recv.mean(), 1),
                                        3),
            "work_max_over_mean": round(work.max() / max(work.mean(), 1),
                                        3),
            "recv_min_over_mean": round(recv.min() / max(recv.mean(), 1),
                                        3),
        })
    return rows


def _col_load(flits: np.ndarray, rows: int, cols: int) -> np.ndarray:
    """Per-column local-link load of the X block (east+west), (cols,)."""
    xb = flits[:N_CHANNELS * rows * cols].reshape(rows, N_CHANNELS, cols)
    return (xb[:, LOCAL_FWD] + xb[:, LOCAL_BWD]).sum(axis=0)


def _topology_rows(g, T: int) -> list[dict]:
    """The torus-vs-mesh-vs-ruche rungs, measured on the live fabric."""
    root = pick_root(g)
    pg = alg.prepare(g, T)
    rows_, cols = grid_shape(T)
    out = []
    for noc in ("ideal", "mesh", "torus", "ruche"):
        # uncapped links: telemetry records the *offered* load per link, so
        # the wiring's hotspot structure is visible (paper Fig. 9 heatmap)
        res = alg.bfs(pg, root, engine_cfg(T=T, noc=noc, link_cap=0))
        s = res.stats
        flits = np.asarray(s.flits_per_link)
        hist = np.asarray(s.hop_histogram)
        used = flits[flits > 0]
        row = {
            "bench": "fig8-topo", "noc": noc,
            "rounds": int(s.rounds),
            "max_link_occupancy": int(s.max_link_occupancy),
            "link_max_over_mean": round(flits.max() / max(used.mean(), 1e-9),
                                        3),
            "avg_hops": round(float((hist * np.arange(len(hist))).sum()
                                    / max(hist.sum(), 1)), 3),
        }
        if noc != "ideal" and cols > 2:
            load = _col_load(flits, rows_, cols)
            interior = load[1:cols - 1].mean()
            boundary = (load[0] + load[cols - 1]) / 2
            row["center_over_edge"] = round(interior / max(boundary, 1e-9), 3)
        out.append(row)
        # finite links: the same wiring under backpressure — spill/replay
        # cost of the hotspot (mesh pays the most, express channels least)
        res_c = alg.bfs(pg, root, engine_cfg(T=T, noc=noc, link_cap=4))
        out.append({
            "bench": "fig8-topo-capped", "noc": noc,
            "rounds": int(res_c.stats.rounds),
            "spills": int(res_c.stats.spills_range
                          + res_c.stats.spills_update),
            "drops": int(res_c.stats.drops),
        })
    return out


def run_hier(scale: int = 10, T: int = 16,
             ndies: tuple[int, int] = (2, 2), g=None) -> list[dict]:
    """The hierarchy column: mesh vs torus vs hier at matched tile counts,
    plus the die-local placement rung on the hier fabric.

    ``die_frac`` is the fraction of fabric injections that cross at least
    one die boundary (from ``Stats.die_crossings``); ``die_flits`` the
    DIE-class express traffic (the hierarchy's scarce resource) — both 0
    by construction on the flat fabrics, which is the comparison: the
    same workload at the same tile count, re-priced by what the wiring
    actually charges for.  Links are uncapped, the same convention as the
    topology rows above: the telemetry records the *offered* load, so the
    placement's locality structure is visible rather than drowned in
    replay re-injections (the capped behavior is fig10's axis).  Rows are
    deterministic at fixed seed, so ``benchmarks/smoke.py`` baselines
    them.  ``g`` lets :func:`run` reuse its already-built graph.
    """
    if g is None:
        g = rmat_graph(scale)
    root = pick_root(g)
    ndies_y, ndies_x = ndies
    rows = []
    corners = [("mesh", "low_order"), ("torus", "low_order"),
               ("hier", "low_order"), ("hier", "low_order_dielocal")]
    pgs = {
        "low_order": alg.prepare(g, T),
        "low_order_dielocal": alg.prepare(g, T, scheme="low_order_dielocal",
                                          dies=ndies),
    }
    for noc, placement in corners:
        cfg = engine_cfg(T=T, noc=noc, link_cap=0, ndies_x=ndies_x,
                         ndies_y=ndies_y)
        res = alg.bfs(pgs[placement], root, cfg)
        s = res.stats
        net = make_network(cfg, T)
        by_cls = flits_by_class(s, net)
        p = perf_cols(s, cfg, T)
        rows.append({
            "bench": "fig8-hier", "noc": noc, "placement": placement,
            "ndies": f"{ndies_y}x{ndies_x}" if noc == "hier" else "1x1",
            "rounds": int(s.rounds),
            "spills": int(np.asarray(s.spills).sum()),
            "drops": int(s.drops),
            "die_frac": round(die_crossing_frac(s), 3),
            "die_flits": by_cls.get("die", 0),
            "local_flits": by_cls.get("local", 0),
            "max_link_occupancy": int(s.max_link_occupancy),
            "cycles": p["cycles"],
            "energy_pj": p["energy_pj"],
            "pj_per_edge": p["pj_per_edge"],
        })
    return rows


def run(scale: int = 10, T: int = 16) -> list[dict]:
    g = rmat_graph(scale)
    rows = _static_rows(g, T, "")
    # the paper's adversarial case: degree-sorted vertex ids
    rows += _static_rows(_sort_by_degree(g), T, "-sorted")
    # dynamic confirmation: run BFS both ways; traffic-balance shows up as
    # fewer spills and fewer rounds for low_order
    root = pick_root(g)
    for scheme in ("low_order", "high_order"):
        pg = alg.prepare(g, T, scheme=scheme)
        res = alg.bfs(pg, root, engine_cfg())
        rows.append({
            "bench": "fig8-dyn", "placement": scheme,
            "rounds": int(res.stats.rounds),
            "spills": int(res.stats.spills_range
                          + res.stats.spills_update),
        })
    # the torus-vs-mesh-vs-ruche rungs (paper Fig. 8/9) on the live fabric
    rows += _topology_rows(g, T)
    # the multi-die hierarchy column (beyond-paper: the composition the
    # paper's >16k-tile scaling implies; PIUMA-style die-of-dies)
    rows += run_hier(scale, T, g=g)
    return rows
