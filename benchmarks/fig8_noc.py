"""Paper Fig. 8/9: NoC traffic balance under the two placements.

The mesh-center hotspot in Fig. 9 is *caused* by skewed per-destination
traffic; the torus/ruche rungs fix the fabric, uniform placement fixes the
source.  We measure the cause directly: the per-destination message
histogram of the first BFS wavefronts under low-order vs high-order
placement (max/mean = endpoint contention; the paper's heatmap in numbers).
Physical torus-vs-mesh wiring cannot be re-measured functionally — the ICI
fabric is fixed; documented in DESIGN.md.
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg
from benchmarks.common import engine_cfg, pick_root, rmat_graph


def _sort_by_degree(g):
    """Adversarial relabeling the paper calls out: vertices sorted by
    degree (hubs get consecutive ids).  Low-order placement must stay
    balanced; high-order concentrates every hub on tile 0."""
    from repro.core.graph import CSRGraph
    deg = g.ptr[1:] - g.ptr[:-1]
    order = np.argsort(-deg)            # new position -> old id
    relabel = np.empty_like(order)
    relabel[order] = np.arange(len(order))
    src = np.repeat(np.arange(g.num_vertices), deg)
    return CSRGraph.from_edges(g.num_vertices, relabel[src],
                               relabel[g.dst], g.val, dedup=False)


def _static_rows(g, T, tag):
    rows = []
    for scheme in ("low_order", "high_order"):
        pg = alg.prepare(g, T, scheme=scheme)
        deg = np.asarray(pg.deg).astype(np.int64)
        dst = np.asarray(pg.edge_dst).reshape(pg.T, -1)
        # traffic each tile RECEIVES: updates to its owned vertices
        owners = np.where(dst >= 0, dst // pg.v_chunk, -1)
        recv = np.bincount(owners[owners >= 0].ravel(), minlength=pg.T)
        work = deg.reshape(pg.T, -1).sum(1)
        rows.append({
            "bench": f"fig8{tag}", "placement": scheme,
            "recv_max_over_mean": round(recv.max() / max(recv.mean(), 1),
                                        3),
            "work_max_over_mean": round(work.max() / max(work.mean(), 1),
                                        3),
            "recv_min_over_mean": round(recv.min() / max(recv.mean(), 1),
                                        3),
        })
    return rows


def run(scale: int = 10, T: int = 16) -> list[dict]:
    g = rmat_graph(scale)
    rows = _static_rows(g, T, "")
    # the paper's adversarial case: degree-sorted vertex ids
    rows += _static_rows(_sort_by_degree(g), T, "-sorted")
    # dynamic confirmation: run BFS both ways; traffic-balance shows up as
    # fewer spills and fewer rounds for low_order
    root = pick_root(g)
    for scheme in ("low_order", "high_order"):
        pg = alg.prepare(g, T, scheme=scheme)
        res = alg.bfs(pg, root, engine_cfg())
        rows.append({
            "bench": "fig8-dyn", "placement": scheme,
            "rounds": int(res.stats.rounds),
            "spills": int(res.stats.spills_range
                          + res.stats.spills_update),
        })
    return rows
