"""Paper Fig. 10 analogue: the energy ladder across placements x fabric
topologies x scheduling policies.

The paper reports joules alongside time for every design point; with the
cycle/energy model (repro.perf) we can do the same.  Each row runs BFS on
one (placement, noc, policy) corner under finite link capacity — so the
backpressure cost of a bad corner (hotspot spills, replay traffic, longer
critical paths) shows up in *both* modeled time and modeled energy, the
paper's two-axis comparison:

* placement — low_order keeps per-destination traffic balanced;
  high_order concentrates hubs (more spills -> more replay energy);
  low_order_dielocal keeps partitions die-resident (cheap on the hier
  fabric, where DIE-class express links carry the energy premium);
* noc — mesh pays the center hotspot, torus wraps pay long-wire energy
  per flit but shorten routes, ruche express channels cut hop counts,
  hier prices die crossings as the scarce expensive resource;
* policy — traffic-aware TSU budgets vs the static round-robin rung.

``pj_per_edge`` is the ladder metric (energy normalized by useful work);
``leak_frac`` splits static leakage from dynamic energy so slow corners
are visibly paying idle-tile leakage, as in the paper's discussion;
``die_frac`` is the fraction of fabric injections crossing a die
boundary (0 on the single-die fabrics).
"""
from __future__ import annotations

from repro.core import algorithms as alg
from repro.perf import die_crossing_frac
from benchmarks.common import engine_cfg, perf_cols, pick_root, rmat_graph, \
    stats_row


def run(scale: int = 10, T: int = 16,
        placements=("low_order", "high_order", "low_order_dielocal"),
        nocs=("ideal", "mesh", "torus", "ruche", "hier"),
        policies=("traffic", "static"),
        ndies: tuple[int, int] = (2, 2)) -> list[dict]:
    g = rmat_graph(scale)
    root = pick_root(g)
    ndies_y, ndies_x = ndies
    rows = []
    pgs = {p: alg.prepare(g, T, scheme=p,
                          dies=ndies if p.endswith("_dielocal") else None)
           for p in placements}
    for placement in placements:
        for noc in nocs:
            for policy in policies:
                hier = noc == "hier"
                cfg = engine_cfg(T=T, noc=noc, policy=policy,
                                 link_cap=0 if noc == "ideal" else 4,
                                 ndies_x=ndies_x if hier else 1,
                                 ndies_y=ndies_y if hier else 1)
                res = alg.bfs(pgs[placement], root, cfg)
                s = stats_row(res.stats)
                p = perf_cols(res.stats, cfg, T)
                rows.append({
                    "bench": "fig10", "placement": placement, "noc": noc,
                    "policy": policy,
                    "rounds": s["rounds"],
                    "cycles": p["cycles"],
                    "time_model_s": p["time_model_s"],
                    "gteps": p["gteps"],
                    "energy_pj": p["energy_pj"],
                    "pj_per_edge": p["pj_per_edge"],
                    "leak_frac": p["leak_frac"],
                    "die_frac": round(die_crossing_frac(res.stats), 3),
                    "spills": s["spills_sum"],
                    "drops": s["drops"],
                })
    return rows
