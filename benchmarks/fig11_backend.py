"""Backend bench: XLA vs Pallas tile-grid execution of the engine round.

Beyond the paper's figures: PR4's Pallas backend re-expresses the round's
queue/scan/fold legs as per-tile kernels (``src/repro/kernels/engine/``)
and PR7 fuses each channel leg into a SINGLE ``pallas_call``
(``EngineConfig.pallas_fuse``); this bench proves three things per
workload x NoC:

* **equivalence** — values, rounds, cycles and energy are bit-identical
  between ``backend="xla"`` and both pallas variants (the ``ok`` column;
  the modeled GTEPS therefore matches by construction);
* **launch accounting** — the ``launches_per_round`` column (from
  ``Stats.launches``): one launch per channel leg fused (3/round for the
  classic program, 5/round for triangles' 4-channel chain) vs the legacy
  4+ standalone kernel dispatches per round on ``pallas-nofuse``, vs 0 on
  xla.  ``benchmarks/kern_micro.py`` prices what each saved launch costs;
* **host cost** — wall-clock per engine run and per round for every
  backend, plus ``fused_round_delta_us`` on the fused rows (the
  wall-clock/round win over the unfused pallas path).  In interpret mode
  the Pallas path pays the interpreter tax on CPU; the columns exist to
  track that overhead (and, on a real TPU with
  ``pallas_interpret=False``, the win) release over release.

The backend strings are "xla", "pallas-nofuse" (``pallas_fuse=False``:
one kernel per building block + XLA glue) and "pallas" (the fused
single-launch leg, the default).  ``nocs`` sweeps the fabric; "hier" runs
the multi-die corner (a 2-die vertical split).

Rows feed ``benchmarks/smoke.py``'s BENCH json (backend=pallas rows in CI)
and the standalone ``BENCH_FIG11.json`` artifact.
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg
from repro.core import reference as ref
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row, timed)

APPS = ("bfs", "sssp", "wcc", "spmv", "pagerank", "kcore", "triangles")
BACKENDS = ("xla", "pallas-nofuse", "pallas")
NOCS = ("ideal", "mesh", "torus", "ruche", "hier")


def _runner(app, g, gs, pg, pgs, pgt, root, x):
    if app == "bfs":
        return lambda cfg: alg.bfs(pg, root, cfg)
    if app == "sssp":
        return lambda cfg: alg.sssp(pg, root, cfg)
    if app == "wcc":
        return lambda cfg: alg.wcc(pgs, cfg)
    if app == "spmv":
        return lambda cfg: alg.spmv(pg, x, cfg)
    if app == "pagerank":
        return lambda cfg: alg.pagerank(pg, iters=3, cfg=cfg)
    if app == "kcore":
        return lambda cfg: alg.kcore(pgs, 2, cfg)
    if app == "triangles":
        return lambda cfg: alg.triangles(pgt, cfg)
    raise ValueError(app)


def _reference(app, g, gs, pgt, root, x):
    if app == "bfs":
        return ref.bfs_ref(g, root)
    if app == "sssp":
        return ref.sssp_ref(g, root)
    if app == "wcc":
        return ref.wcc_ref(gs)
    if app == "spmv":
        return ref.spmv_ref(g, x)
    if app == "kcore":
        return ref.kcore_ref(gs, 2)
    if app == "triangles":
        return ref.triangles_ref(gs, key=pgt.place)
    return None  # pagerank: xla-vs-pallas equivalence is the check


def _cfg(T, noc, backend):
    """Engine config for one (noc, backend-variant) cell — "hier" runs the
    multi-die corner as a 2-die vertical split of the tile grid."""
    kw = dict(ndies_y=2) if noc == "hier" else {}
    if backend == "pallas-nofuse":
        return engine_cfg(T=T, noc=noc, backend="pallas",
                          pallas_fuse=False, **kw)
    return engine_cfg(T=T, noc=noc, backend=backend, **kw)


def run(scale: int = 8, T: int = 8, apps=APPS, nocs=("ideal",),
        backends=BACKENDS, repeat: int = 1, timing: bool = True) -> list[dict]:
    """``timing=False`` drops the machine-dependent wall-clock columns so
    the rows are deterministic — what smoke.py commits to the baseline
    (paired with ``repeat=0``: one engine run per row, no timed re-run)."""
    g = rmat_graph(scale)
    gs = alg.symmetrize(g)
    pg = alg.prepare(g, T)
    pgs = alg.prepare(gs, T)
    pgt = alg.prepare_triangles(gs, T)
    root = pick_root(g)
    x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
    rows = []
    for noc in nocs:
        for app in apps:
            fn = _runner(app, g, gs, pg, pgs, pgt, root, x)
            want = _reference(app, g, gs, pgt, root, x)
            base = None
            nofuse_round_us = None
            for backend in backends:
                cfg = _cfg(T, noc, backend)
                res, wall = timed(fn, cfg, repeat=repeat)
                s = stats_row(res.stats)
                p = perf_cols(res.stats, cfg)
                ok = True
                if want is not None:
                    tol = 1e-4 if app == "spmv" else 0.0
                    ok = bool(np.allclose(res.values, want, rtol=tol,
                                          atol=tol))
                if backend == "xla":
                    base = res
                elif base is not None:
                    # the equivalence contract: every pallas variant ==
                    # xla, bit for bit (launches excluded by design)
                    ok = ok and bool(np.array_equal(res.values,
                                                    base.values)) \
                        and int(res.stats.rounds) == int(base.stats.rounds) \
                        and float(res.stats.cycles) == \
                        float(base.stats.cycles) \
                        and float(res.stats.energy_pj) == \
                        float(base.stats.energy_pj) \
                        and bool(np.array_equal(np.asarray(res.stats.msgs),
                                                np.asarray(base.stats.msgs))) \
                        and bool(np.array_equal(
                            np.asarray(res.stats.spills),
                            np.asarray(base.stats.spills)))
                row = {
                    "bench": "fig11", "app": app, "noc": noc,
                    "backend": backend, "rounds": s["rounds"],
                    "msgs": s["msgs_sum"], "spills": s["spills_sum"],
                    "edges": s["edges_scanned"], "drops": s["drops"],
                    "cycles": p["cycles"], "gteps": p["gteps"],
                    "energy_pj": p["energy_pj"],
                    "ok": ok,
                }
                if backend != "xla":
                    row["launches_per_round"] = round(
                        int(res.stats.launches) / max(s["rounds"], 1), 2)
                if timing:
                    round_us = 1e6 * wall / max(s["rounds"], 1)
                    row["wall_s"] = round(wall, 4)
                    row["round_us"] = round(round_us, 2)
                    if backend == "pallas-nofuse":
                        nofuse_round_us = round_us
                    elif backend == "pallas" and nofuse_round_us is not None:
                        # the fusion win: wall-clock/round saved vs the
                        # unfused pallas path (positive = fused faster)
                        row["fused_round_delta_us"] = round(
                            nofuse_round_us - round_us, 2)
                rows.append(row)
    return rows
