"""Memory-space bench: VMEM-resident vs HBM-streamed edge shards.

Beyond the paper's figures: PR8's memory-space abstraction (``repro.mem``,
DESIGN.md "Memory spaces") lets the per-tile edge shard be *declared* in
VMEM (word-random resident — the implicit assumption every earlier PR
baked in) or in HBM (consumed through double-buffered segment-DMA windows
driven by the prefetched head flits).  This bench runs the ladder per
workload:

* ``rung="vmem"`` — the resident baseline;
* ``rung="hbm-w<window>"`` — the same graph streamed at each DMA window
  size (the auto-sized default plus a max_t2-tight window).

and proves/reports, per row:

* **equivalence** (the ``ok`` column) — HBM rows must be bit-identical to
  the vmem rung in values, rounds, msgs/spills and edges: the space
  changes *where* the shard lives and what it costs, never what the
  program computes.  A pallas-backend HBM row additionally pins backend
  equivalence on the streamed path (bit-identical to the xla HBM row
  including cycles/energy).
* **per-space pricing** — modeled GTEPS and the pJ/edge split by space
  (``pj_per_edge_sram`` / ``pj_per_edge_hbm``; the streamed words priced
  at ``e_hbm``), plus ``dma_windows_round`` (DMA windows fetched per
  round: 2 per delivered range message, the double buffer).
* **the beyond-VMEM run** (``rung="hbm-beyond"``) — the acceptance
  property: under a ``vmem_limit_bytes`` budget the all-VMEM layout
  *rejects at config time* (``Program.validate``; asserted here), the
  HBM layout runs the very same graph end to end, bit-identical in
  values to the unconstrained vmem rung.

Rows feed ``benchmarks/smoke.py`` (BENCH json + the standalone
``BENCH_FIG13.json`` artifact); ``run.py`` runs the full ladder with a
``--fast`` mode.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.program import as_program
from repro.mem import resolve_window
from benchmarks.common import (engine_cfg, perf_cols, pick_root, rmat_graph,
                               stats_row)

APPS = ("bfs", "sssp", "spmv", "kcore")


def _runner(app, pg, pgs, root, x):
    if app == "bfs":
        return lambda cfg: alg.bfs(pg, root, cfg)
    if app == "sssp":
        return lambda cfg: alg.sssp(pg, root, cfg)
    if app == "spmv":
        return lambda cfg: alg.spmv(pg, x, cfg)
    if app == "kcore":
        return lambda cfg: alg.kcore(pgs, 2, cfg)
    raise ValueError(app)


def _reference(app, g, gs, root, x):
    if app == "bfs":
        return ref.bfs_ref(g, root)
    if app == "sssp":
        return ref.sssp_ref(g, root)
    if app == "spmv":
        return ref.spmv_ref(g, x)
    if app == "kcore":
        return ref.kcore_ref(gs, 2)
    return None


def _row(app, rung, space, window, res, cfg, T, ok):
    s = stats_row(res.stats)
    p = perf_cols(res.stats, cfg, T)
    row = {
        "bench": "fig13", "app": app, "rung": rung, "space": space,
        "window": window, "backend": cfg.backend,
        "rounds": s["rounds"], "msgs": s["msgs_sum"],
        "spills": s["spills_sum"], "edges": s["edges_scanned"],
        "drops": s["drops"], "cycles": p["cycles"], "gteps": p["gteps"],
        "energy_pj": p["energy_pj"], "pj_per_edge": p["pj_per_edge"],
        "ok": ok,
    }
    if space == "hbm":
        row["hbm_windows"] = s["hbm_windows"]
        row["hbm_edges"] = s["hbm_edges"]
        row["dma_windows_round"] = round(
            s["hbm_windows"] / max(s["rounds"], 1), 2)
        row["pj_per_edge_sram"] = p.get("pj_per_edge_sram", 0.0)
        row["pj_per_edge_hbm"] = p.get("pj_per_edge_hbm", 0.0)
        row["hbm_frac"] = p.get("hbm_frac", 0.0)
    return row


def _same(res, base) -> bool:
    """The space-equivalence contract: values + the space-independent
    Stats (rounds/msgs/spills/edges) — cycles/energy differ by design
    (that's the pricing split), the per-space counters are what differs."""
    return (bool(np.array_equal(res.values, base.values))
            and int(res.stats.rounds) == int(base.stats.rounds)
            and int(res.stats.edges_scanned) == int(base.stats.edges_scanned)
            and bool(np.array_equal(np.asarray(res.stats.msgs),
                                    np.asarray(base.stats.msgs)))
            and bool(np.array_equal(np.asarray(res.stats.spills),
                                    np.asarray(base.stats.spills))))


def _bit_identical(res, base) -> bool:
    """Backend equivalence on the streamed path: values + cycles/energy
    too (same space, same pricing — launches excluded by design)."""
    return (_same(res, base)
            and float(res.stats.cycles) == float(base.stats.cycles)
            and float(res.stats.energy_pj) == float(base.stats.energy_pj)
            and int(res.stats.hbm_windows) == int(base.stats.hbm_windows)
            and int(res.stats.hbm_edges) == int(base.stats.hbm_edges))


def run(scale: int = 8, T: int = 8, apps=APPS, pallas: bool = True) \
        -> list[dict]:
    g = rmat_graph(scale)
    gs = alg.symmetrize(g)
    pg = alg.prepare(g, T)
    pgs = alg.prepare(gs, T)
    root = pick_root(g)
    x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
    base_cfg = engine_cfg(T=T)
    auto_w = resolve_window(0, base_cfg.max_t2)
    windows = (auto_w, base_cfg.max_t2)  # auto (pow2/granularity) + tight
    rows = []
    for app in apps:
        fn = _runner(app, pg, pgs, root, x)
        want = _reference(app, g, gs, root, x)
        tol = 1e-4 if app == "spmv" else 0.0
        vmem = fn(base_cfg)
        ok = want is None or bool(np.allclose(vmem.values, want, rtol=tol,
                                              atol=tol))
        rows.append(_row(app, "vmem", "vmem", 0, vmem, base_cfg, T, ok))
        hbm_first = None
        for w in windows:
            cfg = engine_cfg(T=T, edge_space="hbm", hbm_window=w)
            res = fn(cfg)
            ok = _same(res, vmem) and int(res.stats.hbm_windows) > 0
            if hbm_first is None:
                hbm_first = res
            rows.append(_row(app, f"hbm-w{w}", "hbm", w, res, cfg, T, ok))
        if pallas:
            cfg = engine_cfg(T=T, edge_space="hbm", hbm_window=windows[0],
                             backend="pallas")
            res = fn(cfg)
            rows.append(_row(app, f"hbm-w{windows[0]}-pallas", "hbm",
                             windows[0], res, cfg, T,
                             _bit_identical(res, hbm_first)))

    # The beyond-VMEM acceptance run (bfs): a per-tile budget the resident
    # edge shard cannot fit — the all-VMEM layout must REJECT at config
    # time, and the HBM layout must run the same graph end to end,
    # bit-identical to the unconstrained vmem rung.
    prog = as_program(alg.BFS)
    hbm_cfg = dataclasses.replace(base_cfg, edge_space="hbm",
                                  hbm_window=base_cfg.max_t2)

    def vmem_bytes(c):
        return sum(b for _, sp, b in prog.tile_decls(c, T, pg.e_chunk,
                                                     pg.v_chunk)
                   if sp == "vmem")

    # a budget squarely between the two footprints: the resident layout
    # must not fit, the streamed one (queues + state + double buffer) must
    limit = (vmem_bytes(hbm_cfg) + vmem_bytes(base_cfg)) // 2
    tight = dataclasses.replace(base_cfg, vmem_limit_bytes=limit)
    try:
        alg.bfs(pg, root, tight)
        raise RuntimeError(
            "fig13: the over-budget all-VMEM config must raise at "
            "Program.validate time, but it ran")
    except ValueError:
        pass  # the config-time rejection the memory budget promises
    cfg = dataclasses.replace(hbm_cfg, vmem_limit_bytes=limit)
    vmem_base = alg.bfs(pg, root, base_cfg)
    res = alg.bfs(pg, root, cfg)
    ok = _same(res, vmem_base) and int(res.stats.hbm_edges) > 0
    rows.append(_row("bfs", "hbm-beyond", "hbm", base_cfg.max_t2, res, cfg,
                     T, ok))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))
