"""Benchmark runner: one section per paper table/figure + the roofline
aggregation.  Prints CSV-ish rows (name, key metrics, derived)."""
from __future__ import annotations

import sys
import time


def _emit(rows):
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()), flush=True)


def main() -> None:
    t0 = time.time()
    fast = "--fast" in sys.argv

    from benchmarks import (fig5_ablation, fig6_scaling, fig7_throughput,
                            fig8_noc, fig10_energy, fig11_backend,
                            fig12_serving, fig13_memspace,
                            fig14_utilization, fig15_adaptive, kern_micro,
                            lm_micro, roofline, taskgraphs,
                            work_efficiency)

    print("# fig5: optimization-ladder ablation (paper Fig. 5)")
    _emit(fig5_ablation.run(scale=8 if fast else 10, T=8 if fast else 16,
                            apps=("bfs",) if fast else fig5_ablation.APPS))
    print("# fig6: strong scaling (paper Fig. 6)")
    _emit(fig6_scaling.run(scale=10 if fast else 12,
                           tiles=(4, 16) if fast else (4, 8, 16, 32, 64)))
    print("# fig7: throughput vs tiles (paper Fig. 7)")
    _emit(fig7_throughput.run(scale=10 if fast else 12,
                              tiles=(4, 16) if fast else (4, 8, 16, 32, 64),
                              apps=("bfs",) if fast else ("bfs", "sssp")))
    print("# fig8: placement / NoC balance (paper Fig. 8-9)")
    _emit(fig8_noc.run(scale=8 if fast else 10, T=8 if fast else 16))
    print("# fig10: energy ladder, placements x topologies x policies "
          "(paper Fig. 10)")
    _emit(fig10_energy.run(
        scale=8 if fast else 10, T=8 if fast else 16,
        nocs=("ideal", "mesh", "hier") if fast else
             ("ideal", "mesh", "torus", "ruche", "hier"),
        policies=("traffic",) if fast else ("traffic", "static")))
    print("# fig11: engine execution backend, xla vs pallas tile-grid "
          "kernels (interpret; fused single-launch legs vs nofuse)")
    _emit(fig11_backend.run(
        scale=8 if fast else 10, T=8 if fast else 16,
        apps=("bfs", "spmv") if fast else fig11_backend.APPS,
        nocs=("ideal", "hier") if fast else fig11_backend.NOCS,
        repeat=1 if fast else 2))
    print("# kern-micro: pallas launch-overhead pricing (fused leg = 1 "
          "launch)")
    _emit(kern_micro.run(n_chain=8 if fast else 32,
                         size=256 if fast else 1024,
                         repeat=1 if fast else 3))
    print("# fig12: query serving — batch width x arrival pattern "
          "(queries/sec, joules/query)")
    _emit(fig12_serving.run(
        scale=8 if fast else 10, T=8 if fast else 16,
        queries=16 if fast else 64,
        widths=(1, 8) if fast else (1, 8, 64),
        arrivals=("burst",) if fast else ("burst", "poisson"),
        pallas_width=0 if fast else 8))
    print("# fig13: memory-space ladder — VMEM-resident vs HBM-streamed "
          "edge shards (double-buffered DMA windows, per-space pricing)")
    _emit(fig13_memspace.run(
        scale=8 if fast else 10, T=8 if fast else 16,
        apps=("bfs", "spmv") if fast else fig13_memspace.APPS,
        pallas=not fast))
    print("# fig14: utilization over time — flight-recorder traces across "
          "noc x placement x policy (per-round util / work CoV)")
    _emit(fig14_utilization.run(
        scale=8 if fast else 10, T=8 if fast else 16,
        ndies=(2, 2),
        combos=fig14_utilization.COMBOS[:2] if fast
        else fig14_utilization.COMBOS))
    print("# fig15: adaptive placement — telemetry-driven migration vs "
          "the static die-local baseline (observe -> migrate -> rerun)")
    _emit(fig15_adaptive.run(scale=8 if fast else 10, T=8 if fast else 16,
                             ndies=(2, 1) if fast else (2, 2)))
    print("# taskgraphs: new workloads on the generic task-program executor")
    _emit(taskgraphs.run(scale=8 if fast else 10, T=8 if fast else 16,
                         ks=(2,) if fast else (2, 3, 4)))
    print("# work-efficiency (paper Section V discussion)")
    _emit(work_efficiency.run(scale=8 if fast else 10, T=8 if fast else 16))
    print("# lm-micro: LM substrate microbenches")
    _emit(lm_micro.run())
    print("# roofline: dry-run derived, paper-faithful BASELINE (pod1)")
    _emit(roofline.run(tag=""))
    print("# roofline: dry-run derived, beyond-paper OPTIMIZED (pod1)")
    _emit(roofline.run(tag="opt"))
    print("# perf: baseline vs optimized per cell")
    _emit(roofline.before_after())
    print("# dry-run multi-pod compile proof (baseline)")
    _emit(roofline.multipod_summary(tag=""))
    print("# dry-run multi-pod compile proof (optimized)")
    _emit(roofline.multipod_summary(tag="opt"))
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
