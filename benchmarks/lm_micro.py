"""LM-substrate micro-benchmarks on CPU (reduced configs): wall time per
call for the core building blocks, plus the Dalorex-dispatch vs dense-MoE
compute ratio (the technique's work saving is architectural — the dispatch
computes k experts/token instead of E)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.moe import moe_block, moe_dense_oracle
from repro.models import transformer as tfm
from benchmarks.common import timed


def run() -> list[dict]:
    rows = []
    # forward/train step wall time per reduced arch family
    for arch in ("granite-3-2b", "mixtral-8x22b", "rwkv6-1.6b",
                 "zamba2-2.7b"):
        cfg = get_config(arch).reduced()
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                  cfg.vocab_size, jnp.int32)
        fwd = jax.jit(lambda p, t: tfm.lm_loss(p, cfg, {"tokens": t})[0])

        def call(p, t):
            return float(fwd(p, t))
        _, dt = timed(call, params, toks, repeat=3)
        rows.append({"bench": "lm_micro", "what": f"loss/{arch}",
                     "us_per_call": round(dt * 1e6, 1)})
    # Dalorex MoE dispatch vs dense-all-experts compute
    E, k, d, ff, B, S = 8, 2, 64, 128, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    params = {
        "router": jax.random.normal(ks[0], (d, E)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, d, ff)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, ff)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, ff, d)) * 0.1,
    }
    x = jax.random.normal(ks[4], (B, S, d))
    disp = jax.jit(lambda p, xx: moe_block(p, xx, E=E, k=k, ff=ff,
                                           mlp="swiglu",
                                           capacity_factor=2.0)[0])
    dense = jax.jit(lambda p, xx: moe_dense_oracle(p, xx, E=E, k=k, ff=ff,
                                                   mlp="swiglu")[0])
    _, dt_disp = timed(lambda: disp(params, x).block_until_ready(),
                       repeat=5)
    _, dt_dense = timed(lambda: dense(params, x).block_until_ready(),
                        repeat=5)
    rows.append({"bench": "lm_micro", "what": "moe_dispatch",
                 "us_per_call": round(dt_disp * 1e6, 1),
                 "dense_us": round(dt_dense * 1e6, 1),
                 "flops_ratio_expected": round(E / k, 2)})
    return rows
