"""Direct unit tests for the repo's small host-side tooling.

Backfill (PR10): ``tools/check_links.py`` and
``benchmarks/common.py::stats_row`` were only exercised indirectly —
through ``test_docs.py`` running the checker over the live docs, and
through the smoke baseline staying byte-stable.  These tests pin the
behaviors directly: the link checker's resolution rules on a synthetic
repo tree, and ``stats_row``'s additive-key discipline — feature counters
(launches, hbm_*, migration_*) appear only on rows whose run actually
exercised the feature, so every pre-feature baseline row stays
byte-stable forever.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.graph import CSRGraph, rmat_edges
from repro.core.engine import EngineConfig
from benchmarks.common import stats_row


def _load_check_links():
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "check_links", os.path.join(here, "tools", "check_links.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture()
def cl(tmp_path, monkeypatch):
    """The checker pointed at a synthetic repo tree under tmp_path."""
    mod = _load_check_links()
    monkeypatch.setattr(mod, "REPO", str(tmp_path))
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "mod.py").write_text("x = 1\n")
    (tmp_path / "docs.md").write_text("see [mod](src/mod.py)\n")
    return mod


# --------------------------------------------------------------------------
# tools/check_links.py
# --------------------------------------------------------------------------

def test_check_links_ok_and_dead(cl, tmp_path):
    assert cl.check_file("docs.md") == []
    (tmp_path / "bad.md").write_text("see [gone](src/gone.py)\n")
    probs = cl.check_file("bad.md")
    assert len(probs) == 1 and "src/gone.py" in probs[0]
    assert "dead link" in probs[0]


def test_check_links_code_tokens(cl, tmp_path):
    (tmp_path / "t.md").write_text(
        "`src/mod.py` is real, `src/nope.py` is not, and `just_code()` "
        "is not a path token at all\n")
    probs = cl.check_file("t.md")
    assert len(probs) == 1 and "src/nope.py" in probs[0]
    assert "dead path" in probs[0]


def test_check_links_module_attr_suffix(cl, tmp_path):
    # src/mod.some_fn resolves through the module file src/mod.py
    (tmp_path / "t.md").write_text("`src/mod.some_fn` and "
                                   "`src/gone.other_fn`\n")
    probs = cl.check_file("t.md")
    assert len(probs) == 1 and "src/gone.other_fn" in probs[0]


def test_check_links_skips_urls_anchors_globs(cl, tmp_path):
    (tmp_path / "t.md").write_text(
        "[web](https://example.com/x) [mail](mailto:a@b.c) [anchor](#top) "
        "`src/*.py` `src/what?.py`\n")
    assert cl.check_file("t.md") == []


def test_check_links_dedups_and_main_exit_codes(cl, tmp_path, capsys):
    (tmp_path / "t.md").write_text("[a](src/gone.py) [b](src/gone.py)\n")
    assert len(cl.check_file("t.md")) == 1  # each target reported once
    assert cl.main(["t.md"]) == 1
    assert cl.main(["docs.md"]) == 0
    assert cl.main(["missing.md"]) == 1
    out = capsys.readouterr().out
    assert "missing.md: file not found" in out


def test_check_links_directory_targets(cl, tmp_path):
    (tmp_path / "t.md").write_text("[dir](src/) `src/`\n")
    assert cl.check_file("t.md") == []


# --------------------------------------------------------------------------
# benchmarks/common.py::stats_row — additive-key discipline.
# --------------------------------------------------------------------------

# counters that must appear ONLY on rows whose run exercised the feature
ADDITIVE_KEYS = ("launches", "hbm_windows", "hbm_edges",
                 "migrated_vertices", "migration_cycles", "migration_pj")


@pytest.fixture(scope="module")
def run():
    n, src, dst, val = rmat_edges(6, edge_factor=4, seed=1)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, 4)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000)
    return alg.bfs(pg, int(np.argmax(g.ptr[1:] - g.ptr[:-1])), cfg), pg, cfg


def test_stats_row_additive_keys_absent_on_plain_run(run):
    res, _, _ = run
    row = stats_row(res.stats)
    # the invariant smoke.py used to gate: additive keys never leak onto
    # rows whose run didn't exercise the feature (xla, vmem, no adapt)
    for k in ADDITIVE_KEYS:
        assert k not in row, f"{k} leaked onto a plain row"
    # and the row is json-clean scalars (what the baselines store)
    json.dumps(row)
    assert row["rounds"] > 0 and "msgs_update" in row


def test_stats_row_serving_keys_additive(run):
    res, _, _ = run
    plain = stats_row(res.stats)
    served = stats_row(res.stats, queries=3, qps=12.34)
    assert "queries" not in plain and "qps" not in plain
    assert served["queries"] == 3 and served["qps"] == 12.3
    assert {k: v for k, v in served.items()
            if k not in ("queries", "qps")} == plain


def test_stats_row_migration_keys_present_after_pricing(run):
    from repro.place import MigrationPlan, price_migration
    res, pg, cfg = run
    real = np.flatnonzero(pg.inv >= 0)[:4]
    plan = MigrationPlan(pairs=real.reshape(2, 2).astype(np.int64))
    priced = price_migration(res.stats, pg, plan, pg.T, params=cfg.perf)
    row = stats_row(priced)
    assert row["migrated_vertices"] > 0
    assert row["migration_cycles"] > 0 and row["migration_pj"] > 0
    # pricing only adds the three migration keys (plus the cycle/energy
    # totals it folds into); nothing else about the row changes
    base = stats_row(res.stats)
    changed = {k for k in row if k not in base
               or row[k] != base[k]}
    assert changed == {"migrated_vertices", "migration_cycles",
                       "migration_pj", "cycles", "energy_pj"}


def test_stats_row_vector_fields_expand(run):
    res, _, _ = run
    row = stats_row(res.stats)
    # per-channel vectors expand to msgs_<i>/spills_<i> plus legacy views
    assert row["msgs_0"] == row["msgs_range"]
    assert row["msgs_1"] == row["msgs_update"]
    assert row["flits_per_link_sum"] == int(
        np.asarray(res.stats.flits_per_link).sum())
