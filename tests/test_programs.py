"""The generic task-program executor: new workloads + program machinery.

Covers the acceptance criteria of the task-model refactor:

* k-core peeling (threshold fold, frontier re-arming decrements) matches
  the sequential peel oracle in async and BSP modes, on ideal and
  physical NoCs, with zero drops;
* 2-hop triangle counting — a 4-channel chain (range -> wedge -> second
  range at the neighbor's owner -> intersection-count fold) the old fixed
  pipeline could not express — matches the numpy oracle exactly, both
  under LocalComm and under the shard_map SPMD path (subprocess, 8 CPU
  devices);
* per-channel Stats counters have the program's channel arity and the
  legacy scalar views still alias the first/last channel;
* Program.min_caps/validate reject undersized channel queues.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.core.program import TRIANGLES, kcore_program, sized_cfg


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=512, cap_updq=4096,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def gs():
    # scale 6 keeps every case non-trivial (k=5 core: 22/64 members;
    # 235 triangles) at a fraction of the scale-7 runtime — tier-1 must
    # stay under ~3 minutes.
    n, src, dst, val = rmat_edges(6, edge_factor=5, seed=2)
    return alg.symmetrize(CSRGraph.from_edges(n, src, dst, val))


@pytest.fixture(scope="module")
def pgs(gs):
    return alg.prepare(gs, T=4)


@pytest.fixture(scope="module")
def pgt(gs):
    return alg.prepare_triangles(gs, T=4)


@pytest.mark.parametrize("k", [2, 5])
@pytest.mark.parametrize("mode", ["async", "bsp"])
def test_kcore_matches_peel_oracle(gs, pgs, k, mode):
    want = ref.kcore_ref(gs, k)
    res = alg.kcore(pgs, k, small_cfg(mode=mode))
    np.testing.assert_array_equal(res.values, want)
    assert int(res.stats.drops) == 0
    assert 0 < int(res.values.sum()) < gs.num_vertices  # non-trivial core


def test_kcore_on_physical_noc(gs, pgs):
    # one physical backend: the peel program's spill-replay interaction is
    # wiring-independent (BFS pins mesh vs torus in test_noc)
    want = ref.kcore_ref(gs, 3)
    res = alg.kcore(pgs, 3, small_cfg(noc="mesh", link_cap=2))
    np.testing.assert_array_equal(res.values, want)
    assert int(res.stats.drops) == 0


def test_triangles_match_oracle(gs, pgt):
    want = ref.triangles_ref(gs, key=pgt.place)
    res = alg.triangles(pgt, small_cfg())
    np.testing.assert_array_equal(res.values, want)
    assert int(res.stats.drops) == 0
    # the 4-channel chain: per-channel counters have the program's arity
    assert np.asarray(res.stats.msgs).shape == (4,)
    assert (np.asarray(res.stats.msgs) > 0).all()
    # total is placement-invariant even though attribution is not
    assert int(res.values.sum()) == int(ref.triangles_ref(gs).sum())


def test_triangles_on_physical_noc(gs, pgt):
    want = ref.triangles_ref(gs, key=pgt.place)
    res = alg.triangles(pgt, small_cfg(noc="mesh", link_cap=2))
    np.testing.assert_array_equal(res.values, want)
    assert int(res.stats.drops) == 0


def test_triangles_high_order_placement(gs):
    pgt2 = alg.prepare_triangles(gs, T=4, scheme="high_order")
    res = alg.triangles(pgt2, small_cfg())
    np.testing.assert_array_equal(res.values,
                                  ref.triangles_ref(gs, key=pgt2.place))


def test_triangles_reject_wrong_partition(gs, pgs):
    """The close fold assumes vertex-aligned, sorted adjacency; any other
    layout must be rejected, not silently miscounted."""
    with pytest.raises(AssertionError, match="prepare_triangles"):
        alg.triangles(pgs, small_cfg())  # equal_edges partition
    pgv = alg.prepare(gs, T=4, edge_mode="vertex_aligned")
    with pytest.raises(AssertionError, match="prepare_triangles"):
        alg.triangles(pgv, small_cfg())  # aligned but unsorted


def test_program_validate_rejects_undersized_queue():
    prog = kcore_program(2)
    cfg = small_cfg(cap_updq=16)
    with pytest.raises(AssertionError, match="worst-case inflow"):
        prog.validate(cfg, 16)
    # sized_cfg raises the knob to the next pow2 that fits
    fixed = sized_cfg(cfg, prog, 16)
    prog.validate(fixed, 16)
    need = prog.min_caps(cfg, 16)[1]
    assert fixed.cap_updq >= need
    assert fixed.cap_updq & (fixed.cap_updq - 1) == 0


def test_legacy_stats_views_alias_channels(pgs, gs):
    res = alg.kcore(pgs, 2, small_cfg())
    s = res.stats
    assert int(s.msgs_range) == int(np.asarray(s.msgs)[0])
    assert int(s.msgs_update) == int(np.asarray(s.msgs)[-1])
    assert int(s.spills_range) == int(np.asarray(s.spills)[0])
    assert int(s.spills_update) == int(np.asarray(s.spills)[-1])


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core import reference as ref
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(6, edge_factor=5, seed=4)
    gs = alg.symmetrize(CSRGraph.from_edges(n, src, dst, val))
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=1024, cap_updq=8192, max_rounds=5000)

    # k-core: SPMD == Local == oracle
    pgs = alg.prepare(gs, T=8)
    want = ref.kcore_ref(gs, 3)
    r_spmd = alg.kcore(pgs, 3, cfg, mesh=mesh)
    r_local = alg.kcore(pgs, 3, cfg)
    np.testing.assert_array_equal(r_spmd.values, r_local.values)
    np.testing.assert_array_equal(r_spmd.values, want)
    assert int(r_spmd.stats.rounds) == int(r_local.stats.rounds)
    assert int(r_spmd.stats.drops) == 0

    # triangles: the 4-channel chain under shard_map
    pgt = alg.prepare_triangles(gs, T=8)
    want = ref.triangles_ref(gs, key=pgt.place)
    t_spmd = alg.triangles(pgt, cfg, mesh=mesh)
    t_local = alg.triangles(pgt, cfg)
    np.testing.assert_array_equal(t_spmd.values, t_local.values)
    np.testing.assert_array_equal(t_spmd.values, want)
    np.testing.assert_array_equal(np.asarray(t_spmd.stats.msgs),
                                  np.asarray(t_local.stats.msgs))
    assert int(t_spmd.stats.drops) == 0
    print("PROGRAM-SPMD-OK")
""")


@pytest.mark.slow
def test_new_workloads_spmd_match_local_and_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PROGRAM-SPMD-OK" in out.stdout
