"""SPMD (shard_map) path == LocalComm emulation, bit for bit.

Multi-device CPU tests must force XLA_FLAGS *before* jax initializes, so
they run in a subprocess; the in-process suite keeps seeing 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core import reference as ref
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=8)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000)
    deg = g.ptr[1:] - g.ptr[:-1]
    root = int(np.argmax(deg))

    # BFS: SPMD == Local == oracle
    r_spmd = alg.bfs(pg, root, cfg, mesh=mesh)
    r_local = alg.bfs(pg, root, cfg)
    np.testing.assert_array_equal(r_spmd.values, r_local.values)
    np.testing.assert_array_equal(r_spmd.values, ref.bfs_ref(g, root))
    assert int(r_spmd.stats.drops) == 0
    # identical round/message counts: the two backends are the same machine
    assert int(r_spmd.stats.rounds) == int(r_local.stats.rounds)
    assert int(r_spmd.stats.msgs_update) == int(r_local.stats.msgs_update)
    # the cycle/energy model accumulates bit-for-bit too (f32 scalars fed
    # by identical psum/pmax reductions)
    assert float(r_spmd.stats.cycles) == float(r_local.stats.cycles)
    assert float(r_spmd.stats.energy_pj) == float(r_local.stats.energy_pj)
    assert float(r_spmd.stats.cycles) > 0

    # SSSP
    s_spmd = alg.sssp(pg, root, cfg, mesh=mesh)
    s_local = alg.sssp(pg, root, cfg)
    np.testing.assert_array_equal(s_spmd.values, s_local.values)

    # SpMV
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    y_spmd = alg.spmv(pg, x, cfg, mesh=mesh)
    np.testing.assert_allclose(y_spmd.values, ref.spmv_ref(g, x), rtol=2e-4,
                               atol=1e-4)

    # physical NoC backends under shard_map: the claims all_gather and the
    # pressure dynamic_slice must behave identically to the vmap emulation
    import dataclasses
    for noc in ("mesh", "torus"):
        ncfg = dataclasses.replace(cfg, noc=noc, link_cap=2)
        n_spmd = alg.bfs(pg, root, ncfg, mesh=mesh)
        n_local = alg.bfs(pg, root, ncfg)
        np.testing.assert_array_equal(n_spmd.values, n_local.values)
        assert int(n_spmd.stats.rounds) == int(n_local.stats.rounds)
        np.testing.assert_array_equal(
            np.asarray(n_spmd.stats.flits_per_link),
            np.asarray(n_local.stats.flits_per_link))
        assert int(n_spmd.stats.drops) == 0
        assert float(n_spmd.stats.cycles) == float(n_local.stats.cycles)
        assert float(n_spmd.stats.energy_pj) == \
            float(n_local.stats.energy_pj)
    print("SPMD-OK")
""")


@pytest.mark.slow
def test_spmd_matches_local_and_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SPMD-OK" in out.stdout
