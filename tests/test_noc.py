"""NoC subsystem invariants: routing correctness, link backpressure,
cross-backend result equivalence, and telemetry conservation.

The system invariants under test:
  * line_usage enumerates exactly the links dimension-ordered travel
    crosses (mesh monotone, torus shorter-way, ruche express-then-local);
  * admit is FIFO and never starves the queue head;
  * one Network round conserves messages: received + spilled == injected,
    and every delivered message lands on its owner tile;
  * under tiny per-link capacities nothing is dropped, spills are replayed
    to completion, and results match the sequential oracles;
  * min-fold workloads are bit-identical across backends (BFS on all
    four, WCC adding the every-vertex frontier); add-folds (PageRank/SpMV)
    agree to float tolerance (delivery rounds differ, so scatter-adds
    re-associate);
  * with no capacity pressure, flit telemetry is conserved:
    sum(flits_per_link) == sum(hops * hop_histogram).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.comm import LocalComm
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.noc import (LOCAL_BWD, LOCAL_FWD, RUCHE_BWD, RUCHE_FWD,
                       Hier2D, IdealAllToAll, Mesh2D, Ruche, Torus2D,
                       admit, grid_shape, line_usage, make_network)

BACKENDS = ("ideal", "mesh", "torus", "ruche")


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=2048,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    # scale 7 keeps every invariant non-trivial (spills under link_cap=1,
    # multi-hop routes on the 2x2 grid) at a fraction of the scale-8
    # runtime — tier-1 must stay under ~3 minutes.
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=0)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)  # 2x2 grid


def root_of(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


# --------------------------------------------------------------------------
# Geometry units.
# --------------------------------------------------------------------------

def test_grid_shape_near_square():
    assert grid_shape(16) == (4, 4)
    assert grid_shape(8) == (2, 4)
    assert grid_shape(5) == (1, 5)
    assert grid_shape(12, rows=3) == (3, 4)
    with pytest.raises(ValueError):
        grid_shape(10, rows=4)


def links(use, chan):
    return np.flatnonzero(np.asarray(use)[0, chan]).tolist()


def test_line_usage_mesh():
    hops, use = line_usage(jnp.array([0]), jnp.array([3]), 4)
    assert int(hops[0]) == 3 and links(use, LOCAL_FWD) == [0, 1, 2]
    hops, use = line_usage(jnp.array([3]), jnp.array([1]), 4)
    assert int(hops[0]) == 2 and links(use, LOCAL_BWD) == [2, 3]
    hops, use = line_usage(jnp.array([2]), jnp.array([2]), 4)
    assert int(hops[0]) == 0 and not np.asarray(use).any()


def test_line_usage_torus_takes_shorter_way():
    # 0 -> 3 on a 4-ring: one hop backward over the wrap link at 0
    hops, use = line_usage(jnp.array([0]), jnp.array([3]), 4, wrap=True)
    assert int(hops[0]) == 1 and links(use, LOCAL_BWD) == [0]
    # 3 -> 1: two hops forward over the wrap (links at 3 and 0)
    hops, use = line_usage(jnp.array([3]), jnp.array([1]), 4, wrap=True)
    assert int(hops[0]) == 2 and links(use, LOCAL_FWD) == [0, 3]


def test_line_usage_ruche_express_then_local():
    # 0 -> 5 with R=2: express hops at 0 and 2, local hop at 4
    hops, use = line_usage(jnp.array([0]), jnp.array([5]), 8, ruche=2)
    assert int(hops[0]) == 3
    assert links(use, RUCHE_FWD) == [0, 2] and links(use, LOCAL_FWD) == [4]
    # backward mirror: 5 -> 0
    hops, use = line_usage(jnp.array([5]), jnp.array([0]), 8, ruche=2)
    assert int(hops[0]) == 3
    assert links(use, RUCHE_BWD) == [3, 5] and links(use, LOCAL_BWD) == [1]


def test_admit_fifo_respects_cap_and_never_starves_head():
    # four messages all crossing link 0: cap=2 admits exactly the first two
    _, use = line_usage(jnp.zeros(4, jnp.int32), jnp.ones(4, jnp.int32), 2)
    valid = jnp.ones(4, bool)
    ok = np.asarray(admit(use, valid, cap=2))
    assert ok.tolist() == [True, True, False, False]
    # invalid rows don't consume capacity
    ok = np.asarray(admit(use, jnp.array([False, True, True, False]), 2))
    assert ok.tolist() == [False, True, True, False]
    # the FIFO head always passes, even at cap=1
    assert bool(admit(use, valid, cap=1)[0])
    # cap<=0 disables the limit
    assert np.asarray(admit(use, valid, cap=0)).all()


# --------------------------------------------------------------------------
# One Network round: conservation + ownership.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("net", [
    IdealAllToAll(8),
    Mesh2D(8, 2, 4, link_cap=1),
    Torus2D(8, 2, 4, link_cap=2),
    Ruche(8, 2, 4, link_cap=1, ruche_factor=2),
    Hier2D(8, 2, 4, link_cap=1, ndies_x=2, ndies_y=1),
])
def test_route_conserves_and_delivers_to_owner(net):
    T, n, chunk = 8, 24, 16
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, T * chunk, (T, n)), jnp.int32)
    msgs = jnp.stack([idx, idx * 7], axis=2)
    valid = jnp.asarray(rng.random((T, n)) < 0.8)
    comm = LocalComm(T)
    r = net.route(comm, msgs, valid, capacity=4,
                  dest_fn=lambda m: m[..., 0] // chunk)
    n_in = int(valid.sum())
    n_recv = int(r.recv_valid.sum())
    n_spill = int(r.spill_valid.sum())
    assert n_recv + n_spill == n_in
    # every delivered message sits on the tile that owns its head index
    owner = np.asarray(r.recv[..., 0]) // chunk
    me = np.arange(T)[:, None]
    rv = np.asarray(r.recv_valid)
    assert (owner[rv] == np.broadcast_to(me, rv.shape)[rv]).all()
    # per-round per-link occupancy respects the cap (psum over tiles)
    if not isinstance(net, IdealAllToAll) and net.link_cap > 0:
        occ = np.asarray(r.link_flits).sum(axis=0)
        assert occ.max() <= net.link_cap


def test_spilled_messages_replay_to_completion(pg, g):
    """link_cap=1 on a 2x2 grid forces heavy spilling; everything must
    still arrive (oracle equality) with zero drops."""
    root = root_of(g)
    # mesh covers monotone lines, torus the wraparound paths; ruche replay
    # is exercised by the link_cap=2 run in the cross-backend test above
    for noc in ("mesh", "torus"):
        res = alg.bfs(pg, root, small_cfg(noc=noc, link_cap=1))
        np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))
        assert int(res.stats.drops) == 0
        assert int(res.stats.spills_range + res.stats.spills_update) > 0


# --------------------------------------------------------------------------
# Cross-backend result equivalence (tiny per-link capacities).
# --------------------------------------------------------------------------

def test_min_folds_bit_identical_across_backends(pg, g):
    """BFS pins the min-fold on every backend; SSSP (weighted emit) and
    WCC (all-vertex frontier) each add one physical backend — enough to
    catch a divergent fold without compiling the full 3-app x 4-backend
    matrix (tier-1 runtime budget)."""
    root = root_of(g)
    gs = alg.symmetrize(g)
    pgs = alg.prepare(gs, T=4)
    base = {n: small_cfg(noc=n, link_cap=2) for n in BACKENDS}
    bfs = {n: alg.bfs(pg, root, c) for n, c in base.items()}
    wcc = {n: alg.wcc(pgs, base[n]) for n in ("ideal", "ruche")}
    for n in BACKENDS:
        assert int(bfs[n].stats.drops) == 0
        np.testing.assert_array_equal(bfs[n].values, bfs["ideal"].values)
    np.testing.assert_array_equal(wcc["ruche"].values, wcc["ideal"].values)
    # SSSP (weighted min-fold) is pinned vs its oracle on the ideal fabric
    # in test_engine; the full 3-app x 4-backend matrix runs below under
    # the slow marker (CI's `-m slow` step).


@pytest.mark.slow  # the full matrix is compile-heavy; tier-1 runs the
def test_min_folds_full_matrix_across_backends(pg, g):  # thinned version
    root = root_of(g)
    gs = alg.symmetrize(g)
    pgs = alg.prepare(gs, T=4)
    base = {n: small_cfg(noc=n, link_cap=2) for n in BACKENDS}
    for app, run in (("bfs", lambda c: alg.bfs(pg, root, c)),
                     ("sssp", lambda c: alg.sssp(pg, root, c)),
                     ("wcc", lambda c: alg.wcc(pgs, c))):
        want = run(base["ideal"])
        for n in BACKENDS[1:]:
            got = run(base[n])
            assert int(got.stats.drops) == 0, (app, n)
            np.testing.assert_array_equal(got.values, want.values,
                                          err_msg=f"{app} on {n}")


def test_add_folds_match_oracle_under_every_backend(pg, g):
    x = np.random.default_rng(1).normal(size=g.num_vertices).astype(
        np.float32)
    y_ref = ref.spmv_ref(g, x.astype(np.float64))
    pr_ref = ref.pagerank_ref(g, iters=3)
    # torus re-associates through its wrap paths too, but its add-fold is
    # the same code path as mesh's; pagerank below runs it instead
    for noc in ("ideal", "mesh", "ruche"):
        cfg = small_cfg(noc=noc, link_cap=2)
        res = alg.spmv(pg, x, cfg)
        np.testing.assert_allclose(res.values, y_ref, rtol=2e-4, atol=1e-4)
    # PR epochs reuse the SpMV-shaped engine run; one physical backend
    # suffices on top of test_engine's ideal-fabric PR oracle check
    res = alg.pagerank(pg, iters=3, cfg=small_cfg(noc="torus", link_cap=2))
    np.testing.assert_allclose(res.values, pr_ref, rtol=2e-3, atol=1e-7)


# --------------------------------------------------------------------------
# Telemetry.
# --------------------------------------------------------------------------

def test_flit_telemetry_conserved_without_spills(pg, g):
    """With generous capacities nothing spills, so every injection travels
    its full path this round: sum(flits) == sum(hops * histogram)."""
    root = root_of(g)
    # torus exercises wrap links, ruche the express channels; mesh's link
    # accounting is the torus code path minus wraps
    for noc in ("ideal", "torus", "ruche"):
        cfg = small_cfg(noc=noc, link_cap=0, cap_route_range=32,
                        cap_route_update=128, cap_rangeq=512,
                        cap_updq=8192)
        res = alg.bfs(pg, root, cfg)
        s = res.stats
        assert int(s.spills_range + s.spills_update) == 0
        flits = np.asarray(s.flits_per_link)
        hist = np.asarray(s.hop_histogram)
        assert flits.sum() == (hist * np.arange(len(hist))).sum()
        assert int(s.max_link_occupancy) <= flits.max()
        if noc == "ideal":
            assert hist[0] == 0  # every delivery is exactly one hop
            assert flits.sum() == int(s.msgs_range + s.msgs_update)


def test_pressure_reads_own_row_and_column():
    net = Mesh2D(16, 4, 4, link_cap=4)
    flits = jnp.zeros((net.num_links,), jnp.int32)
    # load one X-block link in row 2 and one Y-block link in column 1
    from repro.noc import N_CHANNELS
    flits = flits.at[2 * N_CHANNELS * 4 + 3].set(9)       # row 2's line
    flits = flits.at[N_CHANNELS * 16 + 1 * N_CHANNELS * 4 + 2].set(5)
    assert int(net.pressure(jnp.int32(2 * 4 + 1), flits)) == 9  # tile (2,1)
    assert int(net.pressure(jnp.int32(0 * 4 + 1), flits)) == 5  # tile (0,1)
    assert int(net.pressure(jnp.int32(3 * 4 + 3), flits)) == 0  # tile (3,3)


def test_make_network_selects_backend():
    assert isinstance(make_network(small_cfg(noc="ideal"), 16),
                      IdealAllToAll)
    net = make_network(small_cfg(noc="torus", noc_rows=2), 16)
    assert isinstance(net, Torus2D) and (net.rows, net.cols) == (2, 8)
    net = make_network(small_cfg(noc="ruche", ruche_factor=3), 16)
    assert isinstance(net, Ruche) and net.ruche == 3
    with pytest.raises(ValueError):
        make_network(small_cfg(noc="hypercube"), 16)
