"""The policy x mode x noc matrix, plus telemetry shape safety.

The seed suite exercised BSP and static scheduling only on the ideal
crossbar; these tests close the matrix on the physical backends:

* ``policy="static"`` and ``mode="bsp"`` on mesh/torus with finite link
  capacity still match the sequential oracle with zero drops;
* BSP epoch counting is exact (a depth-D chain swaps frontiers D times)
  and identical across backends; async mode never swaps;
* ``zero_stats``/``_acc_stats`` are shape-safe per NoC backend — the
  ``Stats.zero()``-defaults footgun (mixing a (1,)-link zero with
  backend-shaped telemetry, e.g. via ``pagerank(iters=0)``) now raises
  instead of mis-broadcasting.
"""
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig, Stats, zero_stats
from repro.core.graph import CSRGraph, rmat_edges


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=2048,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    # tiny on purpose: the matrix is compile-heavy (each policy x mode x
    # noc corner is its own jit) and tier-1 must stay under ~3 minutes.
    n, src, dst, val = rmat_edges(6, edge_factor=5, seed=1)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)


def root_of(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


@pytest.mark.parametrize("policy,mode,noc", [
    # one physical backend per (policy, mode) corner — alternating mesh /
    # torus keeps both wirings in the matrix at half the compile count
    ("static", "async", "mesh"), ("static", "bsp", "torus"),
    ("traffic", "bsp", "mesh")])
def test_policy_mode_matrix_on_physical_nocs(g, pg, noc, policy, mode):
    root = root_of(g)
    res = alg.bfs(pg, root, small_cfg(noc=noc, link_cap=2, policy=policy,
                                      mode=mode))
    np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))
    assert int(res.stats.drops) == 0
    if mode == "bsp":
        assert int(res.stats.epochs) >= 1


@pytest.mark.pallas
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("noc", ["mesh", "hier"])
def test_backend_closes_matrix_corner(g, pg, backend, noc):
    """The (traffic, async) corner the matrix above leaves open,
    parametrized over the execution backend and over the flat-vs-
    hierarchical fabric (hier = 2x1 dies on the 2x2 grid): every
    combination must reproduce the oracle with zero drops under
    finite-link backpressure (spill/replay through the fused queue
    kernel on the pallas side)."""
    root = root_of(g)
    res = alg.bfs(pg, root, small_cfg(noc=noc, ndies_y=2, link_cap=2,
                                      policy="traffic", mode="async",
                                      backend=backend))
    np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))
    assert int(res.stats.drops) == 0


@pytest.mark.pallas
def test_backend_corner_schedules_identically(g, pg):
    """Same corner, both backends in one process: identical scheduling
    (round count) and values — the compiles are shared with the
    parametrized test above, so this is two cached engine runs."""
    root = root_of(g)
    kw = dict(noc="mesh", link_cap=2, policy="traffic", mode="async")
    rx = alg.bfs(pg, root, small_cfg(backend="xla", **kw))
    rp = alg.bfs(pg, root, small_cfg(backend="pallas", **kw))
    np.testing.assert_array_equal(rx.values, rp.values)
    assert int(rx.stats.rounds) == int(rp.stats.rounds)


def chain_graph(n):
    src = np.arange(n - 1)
    return CSRGraph.from_edges(n, src, src + 1,
                               np.ones(n - 1, np.float32))


@pytest.mark.parametrize("noc", ["ideal", "torus"])
def test_bsp_epoch_count_exact_on_chain(noc):
    """A depth-D chain has D BSP frontier swaps, on every backend; async
    mode never swaps (epochs stays 0)."""
    depth = 7
    g = chain_graph(depth + 1)
    pg = alg.prepare(g, T=4)
    res = alg.bfs(pg, 0, small_cfg(noc=noc, mode="bsp"))
    np.testing.assert_array_equal(res.values, ref.bfs_ref(g, 0))
    assert int(res.stats.epochs) == depth
    if noc == "ideal":  # async-never-swaps is fabric-independent
        res_a = alg.bfs(pg, 0, small_cfg(noc=noc, mode="async"))
        assert int(res_a.stats.epochs) == 0


def test_zero_stats_shapes_match_backend(pg):
    for noc, links in (("ideal", 4), ("mesh", 8 * 4)):
        z = zero_stats(small_cfg(noc=noc), pg.T)
        assert z.flits_per_link.shape == (links,)


def test_pagerank_zero_iters_is_backend_shaped(g, pg):
    cfg = small_cfg(noc="mesh")
    res0 = alg.pagerank(pg, iters=0, cfg=cfg)
    res1 = alg.pagerank(pg, iters=1, cfg=cfg)
    # iters=0 stats can be accumulated with a real mesh run of the same cfg
    combined = alg._acc_stats(res0.stats, res1.stats)
    assert int(combined.rounds) == int(res1.stats.rounds)
    np.testing.assert_array_equal(np.asarray(combined.flits_per_link),
                                  np.asarray(res1.stats.flits_per_link))


def test_acc_stats_rejects_shape_mismatch(g, pg):
    res = alg.pagerank(pg, iters=1, cfg=small_cfg(noc="mesh"))
    with pytest.raises(ValueError, match="shape mismatch"):
        alg._acc_stats(Stats.zero(), res.stats)  # default (1,)-link zero
