"""PR7 fused round legs: ONE ``pallas_call`` per channel leg.

Twin-sweep evidence on top of test_backend_pallas.py's kernel/engine
layers:

* harness-level — ``fused_leg_call`` runs an arbitrary staged function as
  exactly one launch (measured via the trace-time tally, not assumed),
  round-trips scalar / zero-size / mixed-dtype pytree leaves, and is
  bit-identical with ``pad_lanes=True`` ((8,128) lane-tile padding);
* engine-level — ``pallas_fuse=True`` (the default) vs the legacy
  ``pallas_fuse=False`` four-kernel path vs xla: values AND the full
  Stats tuple (minus ``launches``, backend-dependent by design) across
  ragged tails, empty frontiers, finite-link spill/replay and
  duplicate-index add folds;
* launch accounting — pinned counts: the classic program runs 3
  launches/round fused (one per leg) vs 5 unfused, triangles' 4-channel
  chain runs 5/round fused; xla runs 0.  Serving lanes (B>1 vmap) keep
  the same per-trace count.
* degenerate queue — ``queue_push_pop`` with cap-0 data takes the
  explicit early-out (no launch) and matches the XLA twin's empty-slice
  semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.core.queues import queue_make, queue_push, queue_take_front
from repro.kernels.engine import fused_leg_call, queue_push_pop, tally

pytestmark = pytest.mark.pallas


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=4096,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


# --------------------------------------------------------------------------
# Harness-level: fused_leg_call is one launch and a faithful pytree wrapper.
# --------------------------------------------------------------------------

def _staged(scalars, arrays):
    """A stage-shaped function: tuple-of-tuples in, mixed dtypes, a scalar
    and a zero-size leaf on both sides."""
    k, flag = scalars
    m, z, f = arrays
    out = jnp.where(flag, m + k, m - k)
    return (out.sum(), (out, z[:0], f * 2.0))


def _staged_args():
    rng = np.random.default_rng(7)
    scalars = (jnp.int32(3), jnp.asarray(True))
    arrays = (jnp.asarray(rng.integers(0, 9, (5, 7)), jnp.int32),
              jnp.zeros((0, 4), jnp.float32),
              jnp.asarray(rng.random(13), jnp.float32))
    return scalars, arrays


@pytest.mark.parametrize("pad_lanes", [False, True])
def test_fused_leg_call_single_launch_bit_identical(pad_lanes):
    scalars, arrays = _staged_args()
    want = jax.jit(_staged)(scalars, arrays)
    with tally() as t:
        got = jax.jit(lambda s, a: fused_leg_call(
            _staged, s, a, interpret=True, pad_lanes=pad_lanes))(
                scalars, arrays)
    assert t.n == 1, "a fused leg must be exactly ONE pallas_call"
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_leg_call_under_vmap_stays_one_launch():
    """LocalComm batches the per-tile stage with vmap: the fused leg must
    stay a single (gridded) launch and match the unbatched results."""
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.random((4, 6)), jnp.float32)
    fn = lambda x: (x * 2 + 1, x.sum())
    with tally() as t:
        got = jax.vmap(lambda x: fused_leg_call(fn, x, interpret=True))(xs)
    assert t.n == 1
    want = jax.vmap(fn)(xs)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Degenerate queue: cap-0 early-out.
# --------------------------------------------------------------------------

def test_queue_push_pop_cap0_matches_xla_and_skips_launch():
    rows = jnp.asarray([[1, 2], [3, 4], [5, 6]], jnp.int32)
    valid = jnp.asarray([True, False, True])
    q = queue_make(0, 2)
    q1, d1 = queue_push(q, rows, valid)
    t1, tv1, q1 = queue_take_front(q1, jnp.int32(2), 4)
    with tally() as t:
        t2, tv2, ndata, ncount, d2 = queue_push_pop(
            q.data, q.count, rows, valid, jnp.int32(2), 4)
    assert t.n == 0, "cap-0 early-out must not dispatch a kernel"
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(tv1), np.asarray(tv2))
    assert t2.shape[0] == 0 and ndata.shape[0] == 0
    assert int(d1) == int(d2) == 2   # every valid row dropped
    assert int(q1.count) == int(ncount) == 0


# --------------------------------------------------------------------------
# Engine-level: fused == nofuse == xla, plus pinned launch counts.
# --------------------------------------------------------------------------

def assert_stats_identical(a, b, where=""):
    for f, x, y in zip(a._fields, a, b):
        if f == "launches":
            continue  # backend-dependent by design
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"Stats.{f} differs {where}")


@pytest.fixture(scope="module")
def g():
    n, src, dst, val = rmat_edges(6, edge_factor=5, seed=1)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)


def run_app(app, g, pg, cfg):
    if app == "bfs":
        root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
        return alg.bfs(pg, root, cfg)
    if app == "spmv":
        x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
        return alg.spmv(pg, x, cfg)
    if app == "pagerank":
        return alg.pagerank(pg, iters=2, cfg=cfg)
    raise ValueError(app)


@pytest.mark.parametrize("app,noc", [
    ("bfs", "torus"),      # min fold + finite links: spill/replay in-leg
    ("spmv", "ideal"),     # add fold, duplicate indices, single epoch
    ("pagerank", "ideal"),  # multi-epoch add fold
])
def test_fused_twin_sweep_with_pinned_launch_counts(g, pg, app, noc):
    kw = dict(noc=noc, link_cap=2) if noc != "ideal" else dict(noc=noc)
    rx = run_app(app, g, pg, small_cfg(backend="xla", **kw))
    rn = run_app(app, g, pg, small_cfg(backend="pallas",
                                       pallas_fuse=False, **kw))
    rf = run_app(app, g, pg, small_cfg(backend="pallas", **kw))
    np.testing.assert_array_equal(rx.values, rn.values)
    np.testing.assert_array_equal(rx.values, rf.values)
    assert_stats_identical(rx.stats, rn.stats, f"(nofuse, {app}, {noc})")
    assert_stats_identical(rx.stats, rf.stats, f"(fused, {app}, {noc})")
    assert int(rf.stats.drops) == 0
    # the launch-accounting pins (classic program: K=2 channels -> 3 legs)
    rounds = int(rf.stats.rounds)
    assert int(rx.stats.launches) == 0
    assert int(rf.stats.launches) == 3 * rounds, \
        "fused classic leg must be exactly ONE launch per leg"
    assert int(rn.stats.launches) == 5 * rounds
    assert rounds == int(rx.stats.rounds)


def test_empty_frontier_fused(pg):
    g_iso = CSRGraph.from_edges(8, np.array([0]), np.array([1]),
                                np.ones(1, np.float32))
    pgi = alg.prepare(g_iso, T=4)
    rx = alg.bfs(pgi, 7, small_cfg(backend="xla"))
    rf = alg.bfs(pgi, 7, small_cfg(backend="pallas"))
    np.testing.assert_array_equal(rx.values, rf.values)
    assert_stats_identical(rx.stats, rf.stats, "(empty frontier, fused)")
    assert int(rf.stats.launches) == 3 * int(rf.stats.rounds)


def test_pad_lanes_engine_bit_identical(g, pg):
    """(8,128) lane-tile padding changes the kernel block shapes only —
    values, Stats AND the launch count stay identical."""
    rx = run_app("bfs", g, pg, small_cfg(backend="xla"))
    rf = run_app("bfs", g, pg, small_cfg(backend="pallas"))
    rp = run_app("bfs", g, pg, small_cfg(backend="pallas",
                                         pallas_pad_lanes=True))
    np.testing.assert_array_equal(rx.values, rp.values)
    assert_stats_identical(rx.stats, rp.stats, "(pad_lanes)")
    assert int(rp.stats.launches) == int(rf.stats.launches) > 0


def test_serving_lanes_fused_matches_xla(g, pg):
    """B=3 batched query lanes (vmap over the lane axis on top of the tile
    vmap): the fused leg still matches xla per lane, bit for bit."""
    from repro.serve import multi_source
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    srcs = np.argsort(-deg)[:3].astype(np.int64)
    bx = multi_source(pg, "bfs", srcs, small_cfg(backend="xla"))
    bf = multi_source(pg, "bfs", srcs, small_cfg(backend="pallas"))
    np.testing.assert_array_equal(np.asarray(bx.values),
                                  np.asarray(bf.values))
    np.testing.assert_array_equal(np.asarray(bx.stats.rounds),
                                  np.asarray(bf.stats.rounds))
    assert not np.asarray(bx.stats.launches).any()
    assert np.asarray(bf.stats.launches).sum() > 0


# --------------------------------------------------------------------------
# Deep chain: triangles' 4-channel program -> 5 legs -> 5 launches/round.
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_triangles_fused_launch_count(g):
    gs = alg.symmetrize(g)
    pgt = alg.prepare_triangles(gs, T=4)
    rx = alg.triangles(pgt, small_cfg(backend="xla"))
    rf = alg.triangles(pgt, small_cfg(backend="pallas"))
    np.testing.assert_array_equal(rx.values, rf.values)
    assert_stats_identical(rx.stats, rf.stats, "(triangles, fused)")
    assert int(rf.stats.launches) == 5 * int(rf.stats.rounds)
