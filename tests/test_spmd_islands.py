"""SPMD correctness of the Dalorex LM islands (routed embedding, MoE
dispatch, pipeline) on 8 forced CPU devices — subprocess, like test_spmd."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.embedding import embed_lookup, place_table
    from repro.core.moe import (moe_block, moe_dense_oracle,
                                to_dispatch_layout)
    from repro.parallel.sharding import (SINGLE_POD_RULES, mesh_context)

    from repro.launch.mesh import auto_mesh
    mesh = auto_mesh((2, 4), ("data", "model"))
    rules = SINGLE_POD_RULES

    # ---- routed embedding == plain gather ----
    V, d, B, S = 64, 16, 4, 32
    M = 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    table = jax.random.normal(ks[0], (V, d), jnp.float32)
    ids = jax.random.randint(ks[1], (B, S), 0, V, jnp.int32)
    placed = jnp.asarray(place_table(np.asarray(table), M))
    with mesh_context(mesh, rules):
        def f(t, i):
            emb, ovf = embed_lookup(t, i, routed=True,
                                    capacity_factor=4.0)
            return emb, ovf
        t_sh = jax.device_put(placed, NamedSharding(mesh, P("model", None)))
        i_sh = jax.device_put(ids, NamedSharding(mesh, P("data", "model")))
        emb, ovf = jax.jit(f)(t_sh, i_sh)
    assert int(ovf) == 0, int(ovf)
    # oracle: plain gather from the UNPLACED table, using placed ids:
    # placed[(v % M)*chunk + v//M] = table[v]
    expect = np.asarray(table)[np.asarray(ids)]
    np.testing.assert_allclose(np.asarray(emb), expect, rtol=1e-6, atol=1e-6)
    print("EMB-OK")

    # ---- routed-embedding gradient flows to the right rows ----
    with mesh_context(mesh, rules):
        def loss(t):
            emb, _ = embed_lookup(t, i_sh, routed=True, capacity_factor=4.0)
            return (emb ** 2).sum()
        g = jax.jit(jax.grad(loss))(t_sh)
    g_np = np.asarray(g)
    # oracle grad: 2*table[v] summed per occurrence, scattered to placed rows
    expect_g = np.zeros_like(g_np)
    chunk = V // M
    for v in np.asarray(ids).ravel():
        p = (v % M) * chunk + v // M
        expect_g[p] += 2 * np.asarray(table)[v]
    np.testing.assert_allclose(g_np, expect_g, rtol=1e-5, atol=1e-5)
    print("EMB-GRAD-OK")

    # ---- MoE dispatch (E > M: eps=2) == dense oracle ----
    E, k, dm, ff = 8, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    oracle_params = {
        "router": jax.random.normal(ks[0], (dm, E)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, dm, ff)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, dm, ff)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, ff, dm)) * 0.1,
    }
    x = jax.random.normal(ks[4], (4, 32, dm))
    disp = to_dispatch_layout(oracle_params, E, 4)
    with mesh_context(mesh, rules):
        y, aux, ovf = jax.jit(lambda p, xx: moe_block(
            p, xx, E=E, k=k, ff=ff, mlp="swiglu",
            capacity_factor=8.0))(disp, x)
    y_ref, aux_ref = moe_dense_oracle(oracle_params, x, E=E, k=k, ff=ff,
                                      mlp="swiglu")
    assert int(ovf) == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    print("MOE-EPS-OK")

    # ---- MoE dispatch (E < M: expert-TP, tp=2) == dense oracle ----
    E2 = 2
    op2 = {
        "router": jax.random.normal(ks[0], (dm, E2)) * 0.1,
        "w_gate": oracle_params["w_gate"][:E2],
        "w_up": oracle_params["w_up"][:E2],
        "w_down": oracle_params["w_down"][:E2],
    }
    disp2 = to_dispatch_layout(op2, E2, 4)
    with mesh_context(mesh, rules):
        y2, _, ovf2 = jax.jit(lambda p, xx: moe_block(
            p, xx, E=E2, k=1, ff=ff, mlp="swiglu",
            capacity_factor=8.0))(disp2, x)
    y2_ref, _ = moe_dense_oracle(op2, x, E=E2, k=1, ff=ff, mlp="swiglu")
    assert int(ovf2) == 0
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_ref),
                               rtol=2e-4, atol=2e-4)
    print("MOE-TP-OK")

    # ---- pipeline over 8 stages == sequential ----
    from repro.parallel.pipeline import pipeline_apply
    pmesh = auto_mesh((8,), ("stage",))
    n_st, n_micro, mb, dd = 8, 16, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    w = jax.random.normal(ks[0], (n_st, dd, dd)) * 0.3
    xs = jax.random.normal(ks[1], (n_micro, mb, dd))
    stage = lambda wi, xx: jnp.tanh(xx @ wi)
    y_pipe = jax.jit(lambda w, xs: pipeline_apply(
        stage, w, xs, mesh=pmesh, axis="stage", n_micro=n_micro))(w, xs)
    y_seq = xs
    for i in range(n_st):
        y_seq = jax.vmap(lambda xx: stage(w[i], xx))(y_seq)
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                               rtol=2e-5, atol=2e-5)
    print("PIPE-OK")

    # pipeline is differentiable
    gfn = jax.jit(jax.grad(lambda w: pipeline_apply(
        stage, w, xs, mesh=pmesh, axis="stage",
        n_micro=n_micro).sum()))
    gw = gfn(w)
    gseq = jax.grad(lambda w: _seq(w))(w) if False else None
    def seq_loss(w):
        y = xs
        for i in range(n_st):
            y = jax.vmap(lambda xx: stage(w[i], xx))(y)
        return y.sum()
    gw_ref = jax.grad(seq_loss)(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               rtol=2e-4, atol=2e-4)
    print("PIPE-GRAD-OK")
""")


@pytest.mark.slow
def test_spmd_islands():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-5000:]
    for tag in ("EMB-OK", "EMB-GRAD-OK", "MOE-EPS-OK", "MOE-TP-OK",
                "PIPE-OK", "PIPE-GRAD-OK"):
        assert tag in out.stdout, (tag, out.stdout)


RING_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.layers import blockwise_attention
from repro.parallel.ring import ring_attention

from repro.launch.mesh import auto_mesh
mesh = auto_mesh((2, 4), ("data", "model"))
for (B, S, H, Hkv, hd, win) in [(2, 64, 4, 2, 16, 0), (2, 64, 4, 4, 16, 24),
                                (4, 128, 2, 1, 32, 0)]:
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    ref = blockwise_attention(
        q, jnp.repeat(k, H // Hkv, 2), jnp.repeat(v, H // Hkv, 2),
        jnp.arange(S), window=win)
    ring = jax.jit(lambda q, k, v: ring_attention(
        q, k, v, mesh=mesh, batch_axes=("data",), window=win))(q, k, v)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    # gradients flow through the ring (ppermute transpose)
    g = jax.jit(jax.grad(lambda q: ring_attention(
        q, k, v, mesh=mesh, batch_axes=("data",), window=win).sum()))(q)
    gr = jax.grad(lambda q: blockwise_attention(
        q, jnp.repeat(k, H // Hkv, 2), jnp.repeat(v, H // Hkv, 2),
        jnp.arange(S), window=win).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=2e-3, atol=2e-3)
print("RING-OK")
"""


@pytest.mark.slow
def test_ring_attention_matches_blockwise():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", RING_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-5000:]
    assert "RING-OK" in out.stdout
