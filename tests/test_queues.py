"""Property tests (hypothesis) for the queue/routing invariants.

The system invariants under test:
  * queues preserve FIFO order and never lose accepted entries,
  * occurrence_index assigns FIFO per-destination slot ranks,
  * route_tasks conserves messages: sent + spilled == valid, and every
    message arrives at the shard that owns its head index.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev extra (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.comm import LocalComm
from repro.core.queues import (occurrence_index, queue_make, queue_push,
                               queue_take_front)
from repro.core.routing import route_tasks


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1), min_size=1, max_size=40), st.data())
def test_queue_push_take_fifo(mask_list, data):
    n = len(mask_list)
    cap = data.draw(st.integers(1, 50))
    q = queue_make(cap, 2)
    rows = jnp.stack([jnp.arange(n, dtype=jnp.int32),
                      jnp.arange(n, dtype=jnp.int32) * 10], axis=1)
    mask = jnp.asarray(mask_list, bool)
    q, dropped = queue_push(q, rows, mask)
    expect = [i for i, m in enumerate(mask_list) if m][:cap]
    assert int(q.count) == len(expect)
    assert int(dropped) == sum(mask_list) - len(expect)
    taken, tvalid, q2 = queue_take_front(q, jnp.int32(len(expect)), cap)
    got = np.asarray(taken[np.asarray(tvalid)])[:, 0].tolist()
    assert got == expect
    assert int(q2.count) == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.booleans()),
                min_size=1, max_size=64))
def test_occurrence_index_is_fifo_rank(items):
    dest = jnp.asarray([d for d, _ in items], jnp.int32)
    valid = jnp.asarray([v for _, v in items], bool)
    occ = np.asarray(occurrence_index(dest, valid, 4))
    seen = {}
    for i, (d, v) in enumerate(items):
        if v:
            assert occ[i] == seen.get(d, 0)
            seen[d] = seen.get(d, 0) + 1
        else:
            assert occ[i] >= len(items)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 8), st.data())
def test_route_conserves_messages(T, capacity, data):
    n = data.draw(st.integers(1, 32))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    # message head = global index in a T*chunk space; dest = owner
    chunk = 16
    idx = rng.integers(0, T * chunk, size=(T, n))
    payload = rng.integers(0, 1000, size=(T, n))
    valid = rng.random((T, n)) < 0.8
    msgs = jnp.stack([jnp.asarray(idx, jnp.int32),
                      jnp.asarray(payload, jnp.int32)], axis=2)
    dest = jnp.asarray(idx // chunk, jnp.int32)
    comm = LocalComm(T)
    r = route_tasks(comm, msgs, jnp.asarray(valid), dest, capacity)
    sent = int(np.asarray(r.sent).sum())
    spilled = int(np.asarray(r.spill_valid).sum())
    assert sent + spilled == int(valid.sum())
    # delivery: each device receives exactly the sent messages it owns
    recv = np.asarray(r.recv)
    rvalid = np.asarray(r.recv_valid)
    assert rvalid.sum() == sent
    for t in range(T):
        got = recv[t][rvalid[t]]
        assert (got[:, 0] // chunk == t).all()
    # multiset of delivered (idx, payload) pairs == multiset of sent pairs
    sent_rows = []
    spill = np.asarray(r.spill)
    spillv = np.asarray(r.spill_valid)
    for t in range(T):
        for i in range(n):
            if valid[t, i] and not spillv[t, i]:
                sent_rows.append((idx[t, i], payload[t, i]))
    got_rows = [tuple(x) for t in range(T) for x in recv[t][rvalid[t]]]
    assert sorted(sent_rows) == sorted(got_rows)


def test_route_fifo_per_destination():
    """In-order per-channel delivery (wormhole property)."""
    T = 4
    comm = LocalComm(T)
    n = 12
    # all devices send to device 0, increasing payloads
    idx = np.zeros((T, n), np.int64)  # global index 0 -> owner 0 (chunk 4)
    payload = np.arange(n)[None, :].repeat(T, 0)
    msgs = jnp.stack([jnp.asarray(idx, jnp.int32),
                      jnp.asarray(payload, jnp.int32)], axis=2)
    dest = jnp.zeros((T, n), jnp.int32)
    r = route_tasks(comm, msgs, jnp.ones((T, n), bool), dest, capacity=8)
    recv = np.asarray(r.recv[0])
    rvalid = np.asarray(r.recv_valid[0])
    for t in range(T):
        block = recv[t * 8:(t + 1) * 8]
        bv = rvalid[t * 8:(t + 1) * 8]
        pays = block[bv][:, 1]
        assert (np.diff(pays) > 0).all()  # FIFO order preserved
        assert len(pays) == 8  # capacity slots filled
    assert int(np.asarray(r.spill_valid).sum()) == T * (n - 8)
