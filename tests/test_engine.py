"""Engine correctness vs sequential numpy oracles (paper Section IV-B)."""
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=2048,
                max_rounds=5000)
    base.update(kw)
    return EngineConfig(**base)


def make_graph(scale=8, seed=0, ef=6):
    n, src, dst, val = rmat_edges(scale, edge_factor=ef, seed=seed)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def g():
    return make_graph()


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)


def pick_root(g):
    deg = g.ptr[1:] - g.ptr[:-1]
    return int(np.argmax(deg))


def test_bfs_matches_reference(g, pg):
    root = pick_root(g)
    res = alg.bfs(pg, root, small_cfg())
    expect = ref.bfs_ref(g, root)
    np.testing.assert_array_equal(res.values, expect)
    assert int(res.stats.drops) == 0


def test_sssp_matches_reference(g, pg):
    root = pick_root(g)
    res = alg.sssp(pg, root, small_cfg())
    expect = ref.sssp_ref(g, root)
    finite = np.isfinite(expect)
    assert (np.isfinite(res.values) == finite).all()
    np.testing.assert_allclose(res.values[finite], expect[finite], rtol=1e-5)
    assert int(res.stats.drops) == 0


def test_wcc_matches_reference(g):
    gs = alg.symmetrize(g)
    pg = alg.prepare(gs, T=4)
    res = alg.wcc(pg, small_cfg())
    expect = ref.wcc_ref(gs)
    np.testing.assert_array_equal(res.values, expect)


def test_spmv_matches_reference(g, pg):
    rng = np.random.default_rng(1)
    x = rng.normal(size=g.num_vertices).astype(np.float32)
    res = alg.spmv(pg, x, small_cfg())
    expect = ref.spmv_ref(g, x.astype(np.float64))
    np.testing.assert_allclose(res.values, expect, rtol=2e-4, atol=1e-4)


def test_pagerank_matches_reference(g, pg):
    res = alg.pagerank(pg, iters=8, cfg=small_cfg())
    expect = ref.pagerank_ref(g, iters=8)
    np.testing.assert_allclose(res.values, expect, rtol=2e-3, atol=1e-7)


def test_bsp_mode_matches_and_needs_more_rounds(g, pg):
    root = pick_root(g)
    res_async = alg.bfs(pg, root, small_cfg(mode="async"))
    res_bsp = alg.bfs(pg, root, small_cfg(mode="bsp"))
    np.testing.assert_array_equal(res_async.values, res_bsp.values)
    # removing the barrier should never be slower (paper Fig. 5 last rung)
    assert int(res_async.stats.rounds) <= int(res_bsp.stats.rounds)
    assert int(res_bsp.stats.epochs) >= 1


def test_static_policy_correct_but_spillier(g, pg):
    root = pick_root(g)
    res_t = alg.bfs(pg, root, small_cfg(policy="traffic"))
    res_s = alg.bfs(pg, root, small_cfg(policy="static"))
    np.testing.assert_array_equal(res_t.values, res_s.values)
    assert int(res_s.stats.drops) == 0


def test_high_order_placement_correct(g):
    pg2 = alg.prepare(g, T=4, scheme="high_order")
    root = pick_root(g)
    res = alg.bfs(pg2, root, small_cfg())
    np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))


def test_vertex_aligned_edges_correct(g):
    pg3 = alg.prepare(g, T=4, edge_mode="vertex_aligned")
    root = pick_root(g)
    res = alg.bfs(pg3, root, small_cfg())
    np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))
