"""Loss-path equivalence and capacity-limit telemetry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.moe import moe_block
from repro.models.transformer import chunked_xent
from repro.core.engine import EngineConfig


def naive_xent(x, w, labels, mask, z_loss=1e-4):
    logits = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = ((lse - picked) * mask).sum()
    zl = (jnp.square(lse) * mask).sum()
    denom = jnp.maximum(mask.sum(), 1)
    return nll / denom + z_loss * zl / denom


@pytest.mark.parametrize("B,S,d,V,chunk", [(2, 64, 16, 50, 16),
                                           (1, 33, 8, 20, 16),  # ragged
                                           (3, 128, 32, 100, 512)])
def test_chunked_xent_matches_naive(B, S, d, V, chunk):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    x = jax.random.normal(ks[0], (B, S, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V, jnp.int32)
    mask = (jnp.arange(S)[None] < S - 3).astype(jnp.float32) * jnp.ones((B, 1))
    got = chunked_xent(x, w, labels, mask, chunk=chunk)
    expect = naive_xent(x, w, labels, mask)
    np.testing.assert_allclose(float(got), float(expect), rtol=1e-5)


def test_moe_overflow_counter_fires():
    """Starved capacity must be COUNTED (the TSU telemetry), never silent."""
    E, k, d, ff, B, S = 4, 2, 16, 32, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {
        "router": jax.random.normal(ks[0], (d, E)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, d, ff)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, ff)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, ff, d)) * 0.1,
    }
    x = jax.random.normal(ks[4], (B, S, d))
    _, _, ovf_tight = moe_block(params, x, E=E, k=k, ff=ff, mlp="swiglu",
                                capacity_factor=0.25)
    _, _, ovf_loose = moe_block(params, x, E=E, k=k, ff=ff, mlp="swiglu",
                                capacity_factor=8.0)
    assert int(ovf_loose) == 0
    assert int(ovf_tight) > 0


def test_engine_config_validate_rejects_undersized_queue():
    cfg = EngineConfig(cap_updq=64)
    with pytest.raises(AssertionError, match="worst-case T2 burst"):
        cfg.validate(16)


def test_lm_loss_ignores_negative_labels():
    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              num_layers=2)
    from repro.models import transformer as tfm
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size, jnp.int32)
    loss_full, _ = tfm.lm_loss(params, cfg, {"tokens": toks}, remat=False)
    # mask the second half of the labels
    masked = toks.at[:, 8:].set(-1)
    loss_masked, _ = tfm.lm_loss(params, cfg, {"tokens": masked},
                                 remat=False)
    assert np.isfinite(float(loss_masked))
    assert abs(float(loss_masked) - float(loss_full)) > 1e-6
