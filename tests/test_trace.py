"""Flight recorder (repro.trace): the non-perturbation contract and the
host-side consumers.

The recorder's hard promise: ``cfg.trace=True`` never changes what the
engine computes — values AND every ``Stats`` field bit-identical to the
untraced run — on both execution backends (xla / pallas), both comm
backends (LocalComm in-process, shard_map in the slow subprocess test)
and through the serving-lane vmap (each lane's ring == its solo run's).
Plus: ring bounds/cadence semantics, the modeled-cycle timeline
reconciling bitwise with ``Stats.cycles``, the Perfetto/JSONL exporters,
and the additive ``util_mean``/``work_cov`` metric columns.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges

pytestmark = pytest.mark.trace


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=4096,
                max_rounds=5000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def graph():
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(graph):
    return alg.prepare(graph, T=8)


def assert_stats_identical(a, b, note=""):
    for name, x, y in zip(type(a)._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"Stats.{name} {note}")


def _root(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


# --------------------------------------------------------------------------
# Non-perturbation: trace-on == trace-off, bit for bit.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["async", "bsp"])
def test_trace_invariance_xla(graph, pg, mode):
    cfg0 = small_cfg(mode=mode)
    cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)
    r0 = alg.bfs(pg, _root(graph), cfg0)
    r1 = alg.bfs(pg, _root(graph), cfg1)
    assert r0.trace is None and r1.trace is not None
    np.testing.assert_array_equal(r0.values, r1.values)
    assert_stats_identical(r0.stats, r1.stats, f"(mode={mode})")
    np.testing.assert_array_equal(r0.values, ref.bfs_ref(graph,
                                                         _root(graph)))


@pytest.mark.pallas
def test_trace_invariance_pallas(graph, pg):
    cfg0 = small_cfg(backend="pallas")
    cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)
    r0 = alg.bfs(pg, _root(graph), cfg0)
    r1 = alg.bfs(pg, _root(graph), cfg1)
    np.testing.assert_array_equal(r0.values, r1.values)
    assert_stats_identical(r0.stats, r1.stats, "(pallas)")
    assert int(r1.trace.cursor) == int(r0.stats.rounds)
    # pallas rounds dispatch kernels; the recorder must see them
    tr_launch = np.asarray(r1.trace.launches)
    assert tr_launch[np.asarray(r1.trace.round_id) >= 0].min() > 0


def test_trace_invariance_noc_fabrics(graph, pg):
    for noc in ("mesh", "hier"):
        kw = dict(noc=noc)
        if noc == "hier":
            kw.update(ndies_y=2, ndies_x=2)
        cfg0 = small_cfg(**kw)
        cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)
        r0 = alg.sssp(pg, _root(graph), cfg0)
        r1 = alg.sssp(pg, _root(graph), cfg1)
        np.testing.assert_array_equal(r0.values, r1.values)
        assert_stats_identical(r0.stats, r1.stats, f"(noc={noc})")
        # hier routes express DIE-class flits; the per-class split must
        # sum to the same flit totals the links saw
        from repro.trace import trace_arrays
        tr = trace_arrays(r1.trace)
        assert tr["link_cls"].sum() == int(
            np.asarray(r0.stats.flits_per_link).sum())


# --------------------------------------------------------------------------
# Ring semantics: cadence, bounds, wrap.
# --------------------------------------------------------------------------

def test_ring_records_every_round(graph, pg):
    cfg = small_cfg(trace=True, trace_rounds=256)
    r = alg.bfs(pg, _root(graph), cfg)
    n_rounds = int(r.stats.rounds)
    assert int(r.trace.cursor) == n_rounds
    rid = np.asarray(r.trace.round_id)
    got = np.sort(rid[rid >= 0])
    np.testing.assert_array_equal(got, np.arange(n_rounds))


def test_trace_every_cadence(graph, pg):
    cfg = small_cfg(trace=True, trace_rounds=256, trace_every=2)
    r = alg.bfs(pg, _root(graph), cfg)
    rid = np.asarray(r.trace.round_id)
    got = np.sort(rid[rid >= 0])
    want = np.arange(0, int(r.stats.rounds), 2)
    np.testing.assert_array_equal(got, want)


def test_ring_wrap_keeps_last_rounds(graph, pg):
    R = 4
    cfg = small_cfg(trace=True, trace_rounds=R)
    r = alg.bfs(pg, _root(graph), cfg)
    n_rounds = int(r.stats.rounds)
    assert n_rounds > R, "test graph must outlive the tiny ring"
    assert int(r.trace.cursor) == n_rounds  # counts recorded, not slots
    from repro.trace import trace_arrays
    tr = trace_arrays(r.trace)
    assert tr["n_recorded"] == R and tr["n_seen"] == n_rounds
    # the ring holds exactly the LAST R rounds, in time order
    np.testing.assert_array_equal(tr["round_id"],
                                  np.arange(n_rounds - R, n_rounds))


def test_trace_shapes_and_series(graph, pg):
    cfg = small_cfg(trace=True, trace_rounds=64)
    r = alg.bfs(pg, _root(graph), cfg)
    tb = r.trace
    R, T = 64, pg.T
    assert tb.tile_busy.shape == (R, T)
    assert tb.msgs.shape[0] == R and tb.msgs.shape == tb.spills.shape
    from repro.trace import trace_arrays
    tr = trace_arrays(tb)
    # per-channel msgs recorded per round must sum to the Stats totals
    np.testing.assert_array_equal(tr["msgs"].sum(axis=0),
                                  np.asarray(r.stats.msgs))
    np.testing.assert_array_equal(tr["spills"].sum(axis=0),
                                  np.asarray(r.stats.spills))
    # busy cycles are bounded by the round's critical-path envelope
    assert (tr["tile_busy"] <= tr["cyc"][:, None] + 1e-3).all()
    assert (tr["frontier"] >= 0).all() and (tr["pending"] >= 0).all()


# --------------------------------------------------------------------------
# Cycle-timeline reconciliation (the exporter's acceptance contract).
# --------------------------------------------------------------------------

def test_reconcile_cycles_exact(graph, pg):
    from repro.trace import reconcile_cycles
    cfg = small_cfg(trace=True, trace_rounds=256)
    r = alg.bfs(pg, _root(graph), cfg)
    rec = reconcile_cycles(r.trace, float(np.asarray(r.stats.cycles)))
    assert rec["exact"], rec
    # per-round increments also sum to the total (f64 tolerance: the
    # engine's accumulator is Kahan-compensated f32)
    assert rec["increment_rel_err"] < 1e-6


def test_reconcile_detects_wrap(graph, pg):
    from repro.trace import reconcile_cycles
    cfg = small_cfg(trace=True, trace_rounds=4)
    r = alg.bfs(pg, _root(graph), cfg)
    rec = reconcile_cycles(r.trace, float(np.asarray(r.stats.cycles)))
    assert not rec["exact"]  # wrapped ring -> cannot certify the timeline


# --------------------------------------------------------------------------
# Ring edge cases the migration planner leans on (PR10, repro.place).
# --------------------------------------------------------------------------

def test_trace_every_exceeds_trace_rounds(graph, pg):
    """Cadence sparser than the ring (trace_every > trace_rounds) is
    legal: slots fill with every-th rounds only, and the planner's
    signal (:func:`repro.place.score_tiles`) still reads well-formed."""
    from repro.place import score_tiles
    from repro.trace import trace_arrays
    cfg = small_cfg(trace=True, trace_rounds=4, trace_every=8)
    r = alg.bfs(pg, _root(graph), cfg)
    n_rounds = int(r.stats.rounds)
    tr = trace_arrays(r.trace)
    want = np.arange(0, n_rounds, 8)
    assert tr["n_seen"] == tr["n_recorded"] == len(want) <= 4
    np.testing.assert_array_equal(tr["round_id"], want)
    busy = score_tiles(r.trace)
    assert busy.shape == (pg.T,) and busy.sum() > 0


def test_ring_wrap_keeps_last_recorded_not_last_rounds(graph, pg):
    """With a cadence, the ring holds the last R *recorded* (multiple-of-
    every) rounds — not the last R engine rounds."""
    from repro.trace import trace_arrays
    cfg = small_cfg(trace=True, trace_rounds=4, trace_every=2)
    r = alg.bfs(pg, _root(graph), cfg)
    n_rounds = int(r.stats.rounds)
    recorded = np.arange(0, n_rounds, 2)
    assert len(recorded) > 4, "graph must overflow the tiny ring"
    tr = trace_arrays(r.trace)
    assert tr["n_seen"] == len(recorded) and tr["n_recorded"] == 4
    np.testing.assert_array_equal(tr["round_id"], recorded[-4:])


def test_ring_wrap_exactly_at_boundary(graph, pg):
    """R == rounds is an exact fit — full, NOT wrapped, and the whole
    timeline certifies; R == rounds - 1 wraps by one slot and the
    certification is refused, but the last slot still anchors the
    timeline end bitwise (what the epoch-boundary planner reads)."""
    from repro.trace import reconcile_cycles, trace_arrays
    probe = alg.bfs(pg, _root(graph), small_cfg(trace=True,
                                                trace_rounds=1024))
    n_rounds = int(probe.stats.rounds)
    assert 2 < n_rounds < 1024
    fit = alg.bfs(pg, _root(graph), small_cfg(trace=True,
                                              trace_rounds=n_rounds))
    tr = trace_arrays(fit.trace)
    assert tr["n_seen"] == tr["n_recorded"] == n_rounds
    np.testing.assert_array_equal(tr["round_id"], np.arange(n_rounds))
    cycles = float(np.asarray(fit.stats.cycles))
    assert reconcile_cycles(fit.trace, cycles)["exact"]

    short = alg.bfs(pg, _root(graph), small_cfg(trace=True,
                                                trace_rounds=n_rounds - 1))
    tr1 = trace_arrays(short.trace)
    assert tr1["n_seen"] == n_rounds and tr1["n_recorded"] == n_rounds - 1
    np.testing.assert_array_equal(tr1["round_id"], np.arange(1, n_rounds))
    rec = reconcile_cycles(short.trace, cycles)
    assert not rec["exact"]  # round 0 fell off the ring
    assert rec["last_total"] == cycles  # ...but the end anchor survives


def test_reconcile_cycles_mid_epoch_wrap(graph, pg):
    """pagerank restarts the engine per epoch and returns the LAST
    epoch's ring; when that ring wrapped mid-epoch, certification is
    refused, yet the last slot's running total still equals the epoch's
    own cycle cost bitwise — per-epoch cost is structural (every edge
    pushes every epoch), so it matches the single-epoch run and the
    accumulated two-epoch total is exactly twice it."""
    from repro.trace import reconcile_cycles, trace_arrays
    one = alg.pagerank(pg, iters=1, cfg=small_cfg(trace=True,
                                                  trace_rounds=4096))
    two = alg.pagerank(pg, iters=2, cfg=small_cfg(trace=True,
                                                  trace_rounds=4))
    tr = trace_arrays(two.trace)
    assert tr["n_seen"] > tr["n_recorded"]  # wrapped inside the epoch
    total = float(np.asarray(two.stats.cycles))
    rec = reconcile_cycles(two.trace, total)
    assert not rec["exact"]
    per_epoch = float(np.asarray(one.stats.cycles))
    assert rec["last_total"] == per_epoch
    assert total == 2 * per_epoch


# --------------------------------------------------------------------------
# Exporters: Perfetto JSON, JSONL, summary.
# --------------------------------------------------------------------------

def test_perfetto_export(graph, pg, tmp_path):
    from repro.trace import to_perfetto, write_perfetto
    cfg = small_cfg(trace=True, trace_rounds=256, noc="mesh")
    r = alg.bfs(pg, _root(graph), cfg)
    doc = to_perfetto(r.trace, meta={"app": "bfs"})
    ev = doc["traceEvents"]
    phs = {e["ph"] for e in ev}
    assert phs == {"M", "X", "C"}
    # one engine slice + T tile slices per recorded round
    n = int(np.asarray(r.stats.rounds))
    assert sum(e["ph"] == "X" and e["pid"] == 0 for e in ev) == n
    assert sum(e["ph"] == "X" and e["pid"] == 1 for e in ev) == n * pg.T
    # slices tile the timeline: engine slice r starts where r-1 ended
    eng = sorted((e for e in ev if e["ph"] == "X" and e["pid"] == 0),
                 key=lambda e: e["ts"])
    for a, b in zip(eng, eng[1:]):
        assert b["ts"] == pytest.approx(a["ts"] + a["dur"])
    p = tmp_path / "t.perfetto.json"
    write_perfetto(r.trace, str(p), meta={"app": "bfs"})
    assert json.loads(p.read_text())["otherData"]["app"] == "bfs"


def test_jsonl_and_summary(graph, pg, tmp_path):
    from repro.trace import (format_summary, jsonl_rows, summarize,
                             write_jsonl)
    cfg = small_cfg(trace=True, trace_rounds=256)
    r = alg.bfs(pg, _root(graph), cfg)
    rows = jsonl_rows(r.trace)
    assert len(rows) == int(r.stats.rounds)
    assert all(0.0 <= row["util"] <= 1.0 for row in rows)
    p = tmp_path / "t.jsonl"
    assert write_jsonl(r.trace, str(p)) == len(rows)
    back = [json.loads(line) for line in p.read_text().splitlines()]
    assert back == rows
    s = summarize(r.trace)
    assert 0.0 < s["util_mean"] <= 1.0
    assert s["phases"] and sum(p["rounds"] for p in s["phases"]) == len(rows)
    txt = format_summary(s)
    assert "util mean" in txt and "chan" in txt


def test_derived_metrics_additive(graph, pg):
    from repro.perf import derived_metrics
    cfg0 = small_cfg()
    cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)
    r0 = alg.bfs(pg, _root(graph), cfg0)
    r1 = alg.bfs(pg, _root(graph), cfg1)
    plain = derived_metrics(r0.stats, cfg0.perf, pg.T)
    assert "util_mean" not in plain and "work_cov" not in plain
    traced = derived_metrics(r1.stats, cfg1.perf, pg.T, trace=r1.trace)
    assert 0.0 < traced["util_mean"] <= 1.0
    assert traced["work_cov"] >= 0.0
    # additive: the trace columns extend, never reorder/replace
    assert {k: v for k, v in traced.items()
            if k not in ("util_mean", "work_cov")} == plain


# --------------------------------------------------------------------------
# Serving lanes: per-lane rings == solo rings, recycling resets them.
# --------------------------------------------------------------------------

@pytest.mark.serve
def test_serving_lane_traces_match_solo(graph, pg):
    from repro.serve.lanes import multi_source
    from repro.trace import lane_trace
    deg = np.asarray(graph.ptr[1:] - graph.ptr[:-1])
    srcs = np.flatnonzero(deg > 0)[:3].tolist()
    cfg0 = small_cfg()
    cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)
    b0 = multi_source(pg, "bfs", srcs, cfg0)
    b1 = multi_source(pg, "bfs", srcs, cfg1)
    assert b0.trace is None and b1.trace is not None
    np.testing.assert_array_equal(b0.values, b1.values)
    assert_stats_identical(b0.stats, b1.stats, "(lanes B=3)")
    for lane, s in enumerate(srcs):
        solo = alg.bfs(pg, int(s), cfg1)
        lt = lane_trace(b1.trace, lane)
        for name, x, y in zip(type(lt)._fields, lt, solo.trace):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"TraceBuf.{name} lane {lane}")


@pytest.mark.serve
def test_continuous_recycling_resets_lane_ring(graph, pg):
    from repro.serve import Frontend
    deg = np.asarray(graph.ptr[1:] - graph.ptr[:-1])
    srcs = np.flatnonzero(deg > 0)[:5]
    cfg = small_cfg(trace=True, trace_rounds=256)
    fe = Frontend(pg, app="bfs", cfg=cfg, width=2, policy="continuous")
    rep = fe.serve(srcs)  # 5 queries through 2 lanes => recycling happened
    assert rep.queries == 5 and rep.drops == 0
    for rec in rep.records:
        want = ref.bfs_ref(graph, rec.source)
        np.testing.assert_array_equal(rec.values, want)


# --------------------------------------------------------------------------
# shard_map SPMD: replicated trace == LocalComm trace (subprocess).
# --------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=8)
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    cfg0 = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                        cap_route_range=8, cap_route_update=32,
                        cap_rangeq=128, cap_updq=4096, max_rounds=5000)
    cfg1 = dataclasses.replace(cfg0, trace=True, trace_rounds=256)

    # trace-on == trace-off under shard_map
    r0 = alg.bfs(pg, root, cfg0, mesh=mesh)
    r1 = alg.bfs(pg, root, cfg1, mesh=mesh)
    np.testing.assert_array_equal(r0.values, r1.values)
    for f, a, b in zip(type(r0.stats)._fields, r0.stats, r1.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)

    # the SPMD trace == the LocalComm trace, leaf for leaf
    rl = alg.bfs(pg, root, cfg1)
    for f, a, b in zip(type(rl.trace)._fields, rl.trace, r1.trace):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="TraceBuf." + f)

    # serving lanes under shard_map carry the trace too
    from repro.serve.lanes import multi_source
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    srcs = np.flatnonzero(deg > 0)[:2].tolist()
    b_spmd = multi_source(pg, "bfs", srcs, cfg1, mesh=mesh)
    b_loc = multi_source(pg, "bfs", srcs, cfg1)
    np.testing.assert_array_equal(b_spmd.values, b_loc.values)
    for f, a, b in zip(type(b_loc.trace)._fields, b_loc.trace,
                       b_spmd.trace):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="lanes TraceBuf." + f)
    print("SPMD-TRACE-OK")
""")


@pytest.mark.slow
def test_trace_spmd_subprocess():
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SPMD-TRACE-OK" in r.stdout
