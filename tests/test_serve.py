"""Query serving (src/repro/serve/): batched lanes == B solo runs.

The contract under test, per DESIGN.md "Query serving":

* **bit-identity** — every lane of a batched ``multi_source`` run carries
  EXACTLY its solo run's trajectory: values, every per-lane Stats field,
  and the lane's own round count, across the full (backend x noc x mode)
  matrix; duplicate sources and padding lanes included;
* **amortization** — the batch completes in ``max_i rounds_i`` shared
  rounds, strictly fewer than the ``sum_i rounds_i`` a sequential serve
  would cost (the acceptance anchor, pinned at B=64 on both backends);
* **batch clock** — at B=1 the batch makespan/energy degenerate to the
  solo accumulators exactly (the shared round overhead is priced once);
* **front end** — static and continuous policies stream records whose
  rounds/edges/values match solo runs, with monotone latency timestamps
  and drops == 0; continuous recycling never contaminates a lane;
* **rows** — ``stats_row`` keeps its pre-serving keys byte-stable (the
  ``queries``/``qps`` columns are additive).

All tests are marked ``serve`` (their own CI step); the shard_map SPMD
lane test follows tests/test_spmd.py's subprocess pattern and is slow.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.serve import (Frontend, QueryRecord, ServeReport, arrival_cycles,
                         multi_source)

pytestmark = pytest.mark.serve


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=256, cap_updq=4096,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=0)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=8)


def sources_of(g, n, seed=0):
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    return np.random.default_rng(seed).choice(np.flatnonzero(deg > 0),
                                              size=n)


def solo(pg, app, s, cfg):
    return (alg.bfs if app == "bfs" else alg.sssp)(pg, int(s), cfg)


def assert_lane_is_solo(res, lane, ref_res):
    """Lane `lane` of a BatchResult == one solo Result, bit for bit:
    values, the whole Stats tuple, and the round count."""
    np.testing.assert_array_equal(res.values[lane], ref_res.values)
    for name in ref_res.stats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.stats, name))[lane],
            np.asarray(getattr(ref_res.stats, name)),
            err_msg=f"Stats.{name} lane {lane}")


# --------------------------------------------------------------------------
# Bit-identity across the (backend x noc x mode) matrix.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [
    "xla", pytest.param("pallas", marks=pytest.mark.pallas)])
@pytest.mark.parametrize("noc", ["ideal", "mesh"])
@pytest.mark.parametrize("mode", ["async", "bsp"])
def test_batch_lanes_bit_identical_to_solo(g, pg, backend, noc, mode):
    """B=5 batch (two distinct sources, one duplicate, one padding lane)
    == the corresponding solo runs, per lane, on every engine variant."""
    cfg = small_cfg(backend=backend, noc=noc, mode=mode,
                    link_cap=0 if noc == "ideal" else 2)
    srcs = sources_of(g, 3, seed=1)
    batch = [int(srcs[0]), int(srcs[1]), int(srcs[0]), -1, int(srcs[2])]
    res = multi_source(pg, "bfs", batch, cfg)
    ref_runs = {int(s): solo(pg, "bfs", s, cfg) for s in srcs}
    for lane, s in enumerate(batch):
        if s < 0:
            continue
        assert_lane_is_solo(res, lane, ref_runs[s])
        np.testing.assert_array_equal(res.values[lane],
                                      ref.bfs_ref(g, s))
    # duplicate source: the two lanes are bit-identical to each other
    np.testing.assert_array_equal(res.values[0], res.values[2])
    # padding lane: born finished, all-inf, zero everything
    assert np.isinf(res.values[3]).all()
    assert int(np.asarray(res.stats.rounds)[3]) == 0
    assert int(res.done_round[3]) == 0
    # shared rounds = the slowest lane; strictly beats sequential
    lane_rounds = np.asarray(res.stats.rounds)
    assert res.total_rounds == int(lane_rounds.max())
    assert res.total_rounds < res.seq_rounds
    assert int(np.asarray(res.stats.drops).sum()) == 0


def test_batch_lanes_sssp_and_termination_rounds(g, pg):
    """SSSP lanes: per-lane values/stats == solo, and done_round records
    each lane's own termination round (== its solo round count)."""
    cfg = small_cfg()
    srcs = sources_of(g, 4, seed=2)
    res = multi_source(pg, "sssp", srcs, cfg)
    for lane, s in enumerate(srcs):
        r = solo(pg, "sssp", s, cfg)
        assert_lane_is_solo(res, lane, r)
        assert int(res.done_round[lane]) == int(r.stats.rounds)
    assert res.total_rounds == int(np.asarray(res.stats.rounds).max())


def test_multi_source_rejects_non_point_queries(pg):
    with pytest.raises(ValueError, match="bfs/sssp"):
        multi_source(pg, "pagerank", [0], small_cfg())


# --------------------------------------------------------------------------
# The acceptance anchor: B=64 strictly beats 64 sequential runs.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [
    "xla", pytest.param("pallas", marks=pytest.mark.pallas)])
def test_b64_batch_beats_sequential(g, pg, backend):
    cfg = small_cfg(backend=backend)
    srcs = sources_of(g, 64, seed=3)
    res = multi_source(pg, "bfs", srcs, cfg)
    # bit-identical per-query results (one solo run per unique source)
    ref_runs = {int(s): solo(pg, "bfs", s, cfg)
                for s in sorted(set(int(s) for s in srcs))}
    for lane, s in enumerate(srcs):
        assert_lane_is_solo(res, lane, ref_runs[int(s)])
    # strictly fewer shared rounds than 64 sequential runs
    lane_rounds = np.asarray(res.stats.rounds)
    assert res.total_rounds == int(lane_rounds.max())
    assert res.seq_rounds == int(lane_rounds.sum())
    assert res.total_rounds < res.seq_rounds
    assert int(np.asarray(res.stats.drops).sum()) == 0


# --------------------------------------------------------------------------
# Batch clock: B=1 degenerates to the solo accumulators.
# --------------------------------------------------------------------------

def test_b1_batch_clock_equals_solo_accumulators(g, pg):
    cfg = small_cfg()
    s = int(sources_of(g, 1, seed=4)[0])
    res = multi_source(pg, "bfs", [s], cfg)
    r = solo(pg, "bfs", s, cfg)
    # one lane, no sharing: the batch makespan IS the solo cycle count
    assert res.batch_cycles == float(r.stats.cycles)
    assert res.batch_energy_pj == pytest.approx(float(r.stats.energy_pj),
                                                rel=1e-6)
    assert float(res.done_cycle[0]) == res.batch_cycles


def test_batch_clock_sublinear_and_monotone(g, pg):
    """The shared makespan grows with B but strictly sublinearly: B lanes
    pay the per-round overhead once, so the batch beats B solo makespans
    laid end to end."""
    cfg = small_cfg()
    srcs = sources_of(g, 8, seed=5)
    res1 = multi_source(pg, "bfs", srcs[:1], cfg)
    res8 = multi_source(pg, "bfs", srcs, cfg)
    solo_sum = sum(float(solo(pg, "bfs", s, cfg).stats.cycles)
                   for s in srcs)
    assert res8.batch_cycles > res1.batch_cycles  # more work, later finish
    assert res8.batch_cycles < solo_sum           # but amortized
    # per-lane completion stamps are bounded by the makespan
    assert (np.asarray(res8.done_cycle) <= res8.batch_cycles + 1e-3).all()


# --------------------------------------------------------------------------
# Front end: static and continuous policies.
# --------------------------------------------------------------------------

def check_records(g, pg, cfg, app, rep, srcs):
    """Every streamed record matches its solo run (rounds/edges/values)
    and carries monotone enqueue <= admit <= complete timestamps."""
    assert len(rep.records) == len(srcs)
    assert rep.drops == 0
    rf = ref.bfs_ref if app == "bfs" else ref.sssp_ref
    for rec in rep.records:
        r = solo(pg, app, rec.source, cfg)
        assert rec.rounds == int(r.stats.rounds)
        assert rec.edges == int(r.stats.edges_scanned)
        np.testing.assert_array_equal(rec.values, r.values)
        np.testing.assert_array_equal(rec.values, rf(g, rec.source))
        assert rec.enqueue_cycle <= rec.admit_cycle <= rec.complete_cycle
        assert rec.latency >= rec.wait >= 0


@pytest.mark.parametrize("arrival,gap", [("burst", 0.0),
                                         ("uniform", 3000.0)])
def test_frontend_static(g, pg, arrival, gap):
    cfg = small_cfg()
    srcs = sources_of(g, 9, seed=6)
    fe = Frontend(pg, app="bfs", cfg=cfg, width=4)
    rep = fe.serve(srcs, arrival=arrival, gap=gap, seed=0)
    check_records(g, pg, cfg, "bfs", rep, srcs)
    assert rep.batches >= int(np.ceil(len(srcs) / 4))
    if arrival == "burst":  # queries pile up -> batching amortizes rounds
        assert rep.total_rounds < rep.seq_rounds
    else:  # paced wider than the batch makespan: solo batches, no worse
        assert rep.total_rounds <= rep.seq_rounds
    assert rep.qps > 0 and rep.gteps > 0 and rep.j_per_query > 0
    # the row is json-ready: plain python scalars only
    row = rep.row()
    assert row["queries"] == len(srcs) and row["drops"] == 0
    assert row["lat_p50"] <= row["lat_p95"] <= row["lat_max"]


def test_frontend_continuous(g, pg):
    """Continuous batching: lane recycling streams every record with
    solo-identical rounds/edges/values — a freed lane's reuse never
    contaminates its successor query."""
    cfg = small_cfg()
    srcs = sources_of(g, 9, seed=7)
    fe = Frontend(pg, app="bfs", cfg=cfg, width=4, policy="continuous")
    rep = fe.serve(srcs, arrival="poisson", gap=2000.0, seed=0)
    check_records(g, pg, cfg, "bfs", rep, srcs)
    assert rep.total_rounds < rep.seq_rounds
    assert rep.policy == "continuous"


def test_frontend_validation(pg):
    with pytest.raises(ValueError, match="bfs/sssp"):
        Frontend(pg, app="wcc")
    with pytest.raises(ValueError, match="policy"):
        Frontend(pg, policy="adaptive")
    with pytest.raises(ValueError, match="width"):
        Frontend(pg, width=0)
    with pytest.raises(ValueError, match="LocalComm"):
        Frontend(pg, policy="continuous", mesh=object())


def test_arrival_cycles():
    np.testing.assert_array_equal(arrival_cycles(4, "burst"), np.zeros(4))
    np.testing.assert_array_equal(arrival_cycles(3, "uniform", gap=10.0),
                                  [0.0, 10.0, 20.0])
    p1 = arrival_cycles(5, "poisson", gap=100.0, seed=1)
    p2 = arrival_cycles(5, "poisson", gap=100.0, seed=1)
    np.testing.assert_array_equal(p1, p2)  # deterministic at a seed
    assert (np.diff(p1) > 0).all()
    with pytest.raises(ValueError, match="gap"):
        arrival_cycles(3, "uniform")
    with pytest.raises(ValueError, match="unknown"):
        arrival_cycles(3, "weibull", gap=1.0)


# --------------------------------------------------------------------------
# Row plumbing: the serving columns are additive.
# --------------------------------------------------------------------------

def test_stats_row_serving_keys_additive(g, pg):
    from benchmarks.common import stats_row
    res = solo(pg, "bfs", int(sources_of(g, 1)[0]), small_cfg())
    plain = stats_row(res.stats)
    assert "queries" not in plain and "qps" not in plain
    served = stats_row(res.stats, queries=12, qps=345.6)
    assert served["queries"] == 12 and served["qps"] == 345.6
    # the pre-serving keys are untouched — baseline rows stay byte-stable
    assert {k: v for k, v in served.items()
            if k not in ("queries", "qps")} == plain


# --------------------------------------------------------------------------
# shard_map SPMD lanes (subprocess, as in tests/test_spmd.py).
# --------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges
    from repro.serve import Frontend, multi_source

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=8)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000)
    deg = np.asarray(g.ptr[1:] - g.ptr[:-1])
    srcs = np.random.default_rng(0).choice(np.flatnonzero(deg > 0), size=5)
    srcs = np.concatenate([srcs, [-1]])  # padding lane rides along too

    # SPMD batch == LocalComm batch, bit for bit (values + every Stats
    # field + the batch clocks)
    r_spmd = multi_source(pg, "bfs", srcs, cfg, mesh=mesh)
    r_local = multi_source(pg, "bfs", srcs, cfg)
    np.testing.assert_array_equal(r_spmd.values, r_local.values)
    for name in r_spmd.stats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r_spmd.stats, name)),
            np.asarray(getattr(r_local.stats, name)), err_msg=name)
    assert r_spmd.total_rounds == r_local.total_rounds
    assert float(r_spmd.batch_cycles) == float(r_local.batch_cycles)
    assert float(r_spmd.batch_energy_pj) == float(r_local.batch_energy_pj)
    np.testing.assert_array_equal(r_spmd.done_round, r_local.done_round)

    # == solo runs, per lane (the lane contract holds under shard_map)
    for lane, s in enumerate(srcs[:-1]):
        rs = alg.bfs(pg, int(s), cfg, mesh=mesh)
        np.testing.assert_array_equal(r_spmd.values[lane], rs.values)
        assert int(np.asarray(r_spmd.stats.rounds)[lane]) == \\
            int(rs.stats.rounds)
    assert np.isinf(r_spmd.values[-1]).all()

    # the static front end runs on the SPMD path too
    fe = Frontend(pg, app="bfs", cfg=cfg, width=4, mesh=mesh)
    rep = fe.serve(srcs[:-1])
    assert len(rep.records) == 5 and rep.drops == 0
    assert rep.total_rounds < rep.seq_rounds
    print("SERVE-SPMD-OK")
""")


@pytest.mark.slow
def test_spmd_lanes_match_local_and_solo():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SERVE-SPMD-OK" in out.stdout
