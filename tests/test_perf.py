"""The cycle/energy performance model (repro.perf).

Pins down the model's contract:

* per-link class attribution: torus wraparounds and ruche express
  channels are priced differently from local neighbor hops;
* cycles are monotone non-decreasing in rounds (each round costs at
  least ``t_round``);
* on a fixed no-spill workload the fabric ordering holds:
  ideal <= mesh <= torus-with-wrap-penalty;
* the accumulated energy reconciles exactly (f32 rounding aside) with
  the linear formula over the final Stats counters — including under
  heavy spilling, where the replay terms dominate;
* ``stats_row`` surfaces every channel (``msgs_<i>``) with the legacy
  range/update keys as first/last views;
* fig6's ``speedup_vs_linear`` no longer depends on the order of the
  ``tiles`` argument (the unsorted-tiles bug).

The SPMD == LocalComm bit-for-bit check for the new Stats fields lives in
tests/test_spmd.py (subprocess, 8 CPU devices).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from repro.core import algorithms as alg
from repro.core.engine import EngineConfig, Stats
from repro.core.graph import CSRGraph, rmat_edges
from repro.noc import (N_CHANNELS, LOCAL_BWD, LOCAL_FWD, RUCHE_BWD,
                       RUCHE_FWD, Mesh2D, Ruche, Torus2D, make_network)
from repro.perf import (CLASS_LOCAL, CLASS_PORT, CLASS_RUCHE, CLASS_WRAP,
                        PerfParams, energy_from_totals)


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=2048,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=5)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=8)


def root_of(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


# --------------------------------------------------------------------------
# Link-class attribution.
# --------------------------------------------------------------------------

def test_link_classes_price_wrap_and_ruche_differently():
    mesh = Mesh2D(8, 2, 4)
    torus = Torus2D(8, 2, 4)
    ruche = Ruche(8, 2, 4, ruche_factor=2)
    for net in (mesh, torus, ruche):
        assert net.link_classes.shape == (net.num_links,)
    # mesh: local and (never-used) ruche channels only, no wraps
    assert not (mesh.link_classes == CLASS_WRAP).any()
    # torus: each of the `rows` row lines closes its ring with one wrap
    # link per direction, same for the `cols` column lines
    tc = torus.link_classes
    assert (tc == CLASS_WRAP).sum() == 2 * (torus.rows + torus.cols)
    # the wrap links sit exactly where line_usage charges a wraparound:
    # forward at the end of the line, backward at position 0
    xb = tc[:N_CHANNELS * torus.rows * torus.cols].reshape(
        torus.rows, N_CHANNELS, torus.cols)
    assert (xb[:, LOCAL_FWD, -1] == CLASS_WRAP).all()
    assert (xb[:, LOCAL_BWD, 0] == CLASS_WRAP).all()
    assert (xb[:, LOCAL_FWD, :-1] == CLASS_LOCAL).all()
    # ruche express channels are their own class on every backend
    rc = ruche.link_classes.reshape(-1)
    rx = rc[:N_CHANNELS * 8].reshape(2, N_CHANNELS, 4)
    assert (rx[:, RUCHE_FWD] == CLASS_RUCHE).all()
    assert (rx[:, RUCHE_BWD] == CLASS_RUCHE).all()
    # ideal crossbar ports: no wire latency, switch energy only
    ideal = make_network(small_cfg(noc="ideal"), 8)
    assert (ideal.link_classes == CLASS_PORT).all()
    assert PerfParams().hop_cycle_table()[CLASS_PORT] == 0


# --------------------------------------------------------------------------
# Cycle accumulator.
# --------------------------------------------------------------------------

def test_cycles_monotone_in_rounds(g, pg):
    root = root_of(g)
    prev = -1.0
    full = alg.bfs(pg, root, small_cfg())
    for r in (2, 5):
        res = alg.bfs(pg, root, small_cfg(max_rounds=r))
        assert int(res.stats.rounds) == r
        cyc = float(np.asarray(res.stats.cycles))
        # every round costs at least t_round, so more rounds = more cycles
        assert cyc >= prev + (1 if prev >= 0 else 0)
        assert cyc >= float(res.stats.rounds)  # t_round=1 floor
        prev = cyc
    assert float(full.stats.cycles) > prev
    assert float(full.stats.energy_pj) > 0


def test_fabric_cycle_ordering_ideal_mesh_torus(g):
    """On a no-spill fixed workload the wire terms order the fabrics:
    the perfect crossbar adds nothing, the mesh pays local hops, and a
    torus with a punitive wraparound cost pays the most (its shorter-way
    routes concentrate traffic on the expensive wrap links)."""
    pg = alg.prepare(g, T=8)
    root = root_of(g)
    penal = PerfParams(t_hop_wrap=8)
    cyc, rounds = {}, {}
    for noc in ("ideal", "mesh", "torus"):
        cfg = small_cfg(noc=noc, cap_route_range=64, cap_route_update=256,
                        cap_rangeq=1024, cap_updq=8192, perf=penal)
        s = alg.bfs(pg, root, cfg).stats
        assert int(np.asarray(s.spills).sum()) == 0  # apples to apples
        cyc[noc] = float(np.asarray(s.cycles))
        rounds[noc] = int(s.rounds)
    assert rounds["ideal"] == rounds["mesh"] == rounds["torus"]
    assert cyc["ideal"] <= cyc["mesh"] <= cyc["torus"], cyc


# --------------------------------------------------------------------------
# Energy accounting.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("noc,link_cap", [("ideal", 0), ("mesh", 2)])
def test_energy_reconciles_with_stats_totals(g, pg, noc, link_cap):
    root = root_of(g)
    cfg = small_cfg(noc=noc, link_cap=link_cap)
    s = alg.bfs(pg, root, cfg).stats
    if noc == "mesh":
        assert int(np.asarray(s.spills).sum()) > 0  # replay term exercised
    got = float(np.asarray(s.energy_pj))
    want = energy_from_totals(s, cfg.perf, make_network(cfg, pg.T), pg.T)
    assert got == pytest.approx(want, rel=1e-4)


def test_params_are_overridable_and_scale_cost(g, pg):
    root = root_of(g)
    base = alg.bfs(pg, root, small_cfg()).stats
    slow = alg.bfs(pg, root, small_cfg(
        perf=PerfParams(t_sram=8, t_alu=4, e_pop=10.0))).stats
    assert int(slow.rounds) == int(base.rounds)
    assert float(slow.cycles) > float(base.cycles)
    assert float(slow.energy_pj) > float(base.energy_pj)


# --------------------------------------------------------------------------
# Benchmark plumbing: per-channel stats_row keys, fig6 tiles ordering.
# --------------------------------------------------------------------------

def test_stats_row_emits_every_channel():
    from benchmarks.common import stats_row
    import jax.numpy as jnp
    s4 = Stats.zero(num_links=4, max_hops=2, num_channels=4)._replace(
        msgs=jnp.asarray([10, 20, 30, 40], jnp.int32))
    row = stats_row(s4)
    assert [row[f"msgs_{i}"] for i in range(4)] == [10, 20, 30, 40]
    assert "msgs_4" not in row
    assert row["msgs_range"] == 10 and row["msgs_update"] == 40
    assert row["msgs_sum"] == 100 and row["msgs_max"] == 40
    # 1-channel program: the legacy keys alias the same (only) channel
    s1 = Stats.zero(num_channels=1)._replace(
        msgs=jnp.asarray([7], jnp.int32))
    row1 = stats_row(s1)
    assert row1["msgs_0"] == row1["msgs_range"] == row1["msgs_update"] == 7
    # model scalars come through as floats
    assert isinstance(row["cycles"], float)
    assert isinstance(row["energy_pj"], float)


def test_fig6_speedup_invariant_to_tiles_order():
    from benchmarks import fig6_scaling
    rows = fig6_scaling.run(scale=6, tiles=(16, 4))
    assert [r["T"] for r in rows] == [4, 16]  # sorted before use
    assert rows[0]["speedup_vs_linear"] == 1.0  # normalized to smallest T
    assert all(r["cycles"] > 0 and r["energy_pj"] > 0 for r in rows)
    assert all(r["time_model_s"] > 0 and r["gteps"] > 0 for r in rows)
    with pytest.raises(AssertionError, match="duplicate"):
        fig6_scaling.run(scale=6, tiles=(4, 4))
