"""The docs front door stays truthful: every repo-path reference in
README.md / DESIGN.md / benchmarks/README.md resolves to a real file
(the CI link-check step runs the same checker standalone)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..")))

from tools.check_links import REPO, check_file, main

DOCS = ["README.md", "DESIGN.md", "benchmarks/README.md"]


@pytest.mark.parametrize("md", DOCS)
def test_doc_exists_and_links_resolve(md):
    assert os.path.exists(os.path.join(REPO, md)), md
    assert check_file(md) == []


def test_checker_catches_dead_references(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `src/repro/nonexistent.py` and "
                   "[gone](benchmarks/missing_bench.py)\n"
                   "but `src/repro/noc/network.py` and http links "
                   "[ok](https://example.com) are fine\n")
    rel = os.path.relpath(bad, REPO)
    problems = check_file(rel)
    assert len(problems) == 2
    assert any("nonexistent" in p for p in problems)
    assert any("missing_bench" in p for p in problems)


def test_main_is_ci_callable():
    assert main(DOCS) == 0
    assert main(["no/such/doc.md"]) == 1
