"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
against the pure-jnp/numpy oracles, per the kernels/ contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2.kernel import ssd_pallas
from repro.kernels.mamba2.ref import ssd_chunked, ssd_scan_oracle
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_chunked, wkv6_scan_oracle
from repro.kernels.scatter_update.kernel import scatter_segments
from repro.kernels.scatter_update.ref import scatter_ref
from repro.kernels.spmv.kernel import spmv_block_ell
from repro.kernels.spmv.ref import (block_ell_ref, spmv_dense_ref,
                                    to_block_ell)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("B,S,H,Hkv,hd,win,dtype", [
    (2, 256, 4, 2, 64, 0, "float32"),
    (1, 256, 4, 1, 64, 64, "float32"),
    (2, 128, 2, 2, 32, 0, "float32"),
    (1, 512, 8, 8, 64, 128, "float32"),
    (1, 256, 4, 4, 128, 0, "bfloat16"),
])
def test_flash_attention_sweep(B, S, H, Hkv, hd, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((B, S, H)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, window=win)
    ref = attention_ref(q, k, v, window=win)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_shape_independence():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    o1 = flash_attention(q, k, v, block_q=64, block_k=128)
    o2 = flash_attention(q, k, v, block_q=256, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- spmv
@pytest.mark.parametrize("n,nnz,b", [(300, 2000, 64), (513, 4000, 128),
                                     (100, 500, 32)])
def test_spmv_block_ell_sweep(n, nnz, b):
    rng = np.random.default_rng(n)
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, n, nnz)
    vals = rng.normal(size=nnz).astype(np.float32)
    bvals, bcols, n_pad = to_block_ell(n, rows, cols, vals, b)
    x = rng.normal(size=n_pad).astype(np.float32)
    x[n:] = 0
    expect = spmv_dense_ref(n, rows, cols, vals, x[:n])
    np.testing.assert_allclose(block_ell_ref(bvals, bcols, x)[:n], expect,
                               rtol=1e-4, atol=1e-4)
    got = np.asarray(spmv_block_ell(jnp.asarray(bvals), jnp.asarray(bcols),
                                    jnp.asarray(x)))
    np.testing.assert_allclose(got[:n], expect, rtol=1e-4, atol=1e-4)


@pytest.mark.pallas
def test_spmv_block_ell_matches_engine_on_rmat():
    """End-to-end: the standalone block-ELL Pallas kernel and the engine's
    SPMV program compute the same operator on the same RMAT graph.

    The engine pushes y[dst] += val * x[src] over graph edges, so the
    matrix is A[dst, src] = val; both paths are checked against the f64
    dense oracle and against each other (f32 summation orders differ, so
    allclose, not bit-equality)."""
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    n, src, dst, val = rmat_edges(6, edge_factor=4, seed=5)
    g = CSRGraph.from_edges(n, src, dst, val)
    rng = np.random.default_rng(7)
    x = rng.normal(size=g.num_vertices).astype(np.float32)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=20000)
    src_idx = np.repeat(np.arange(g.num_vertices),
                        g.ptr[1:] - g.ptr[:-1])
    expect = spmv_dense_ref(g.num_vertices, g.dst, src_idx, g.val, x)
    for backend in ("xla", "pallas"):  # the engine side, both backends
        pg = alg.prepare(g, T=4)
        res = alg.spmv(pg, x, dataclasses.replace(cfg, backend=backend))
        np.testing.assert_allclose(res.values, expect, rtol=1e-4,
                                   atol=1e-4)
    bvals, bcols, n_pad = to_block_ell(g.num_vertices, g.dst, src_idx,
                                       g.val, block=32)
    x_pad = np.zeros(n_pad, np.float32)
    x_pad[:g.num_vertices] = x
    y = np.asarray(spmv_block_ell(jnp.asarray(bvals), jnp.asarray(bcols),
                                  jnp.asarray(x_pad)))
    np.testing.assert_allclose(y[:g.num_vertices], expect, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(y[:g.num_vertices], res.values, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------- scatter
@pytest.mark.parametrize("op", ["min", "add"])
@pytest.mark.parametrize("nb,b,cap", [(4, 128, 32), (2, 64, 128)])
def test_scatter_segments_sweep(op, nb, b, cap):
    rng = np.random.default_rng(nb * b + cap)
    base = rng.normal(size=(nb, b)).astype(np.float32)
    idx = rng.integers(-1, b, (nb, cap)).astype(np.int32)  # -1 = empty
    vals = rng.normal(size=(nb, cap)).astype(np.float32)
    got = np.asarray(scatter_segments(jnp.asarray(base), jnp.asarray(idx),
                                      jnp.asarray(vals), op=op))
    expect = scatter_ref(base, idx, vals, op)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)


def test_scatter_duplicate_indices():
    base = jnp.zeros((1, 64), jnp.float32)
    idx = jnp.asarray([[3, 3, 3, -1]], jnp.int32)
    vals = jnp.asarray([[1.0, 2.0, 3.0, 9.0]], jnp.float32)
    add = np.asarray(scatter_segments(base, idx, vals, op="add"))
    assert add[0, 3] == 6.0
    mn = np.asarray(scatter_segments(base + 10, idx, vals, op="min"))
    assert mn[0, 3] == 1.0


# ---------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("B,S,H,K,chunk", [(2, 128, 3, 16, 16),
                                           (1, 64, 2, 32, 32),
                                           (2, 96, 1, 64, 16)])
def test_wkv6_kernel_sweep(B, S, H, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + K), 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    w_log = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5),
                     -4.0, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    y_k, s_k = wkv6_pallas(r, k, v, w_log, u, chunk=chunk)
    y_r, s_r = wkv6_scan_oracle(r, k, v, w_log, u)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_kernel_state_carry():
    """Splitting a sequence across two kernel calls == one call."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, K = 1, 64, 2, 16
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    w = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5),
                 -4.0, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    y_full, s_full = wkv6_pallas(r, k, v, w, u, chunk=16)
    h = S // 2
    y1, s1 = wkv6_pallas(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, chunk=16)
    y2, s2 = wkv6_pallas(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u,
                         state0=s1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------- mamba2
@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 128, 3, 16, 8, 16),
                                             (1, 64, 2, 32, 16, 32),
                                             (2, 96, 1, 64, 64, 16)])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + P), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_k, s_k = ssd_pallas(x, dt, a_log, Bm, Cm, chunk=chunk)
    y_r, s_r = ssd_scan_oracle(x, dt, a_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=3e-4, atol=3e-4)


def test_chunked_refs_match_pallas_exactly_same_chunk():
    """ref.*_chunked and the Pallas kernel implement the same algorithm —
    with identical chunking they agree to much tighter tolerance."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, K = 1, 64, 2, 16
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, K))
    w = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5),
                 -4.0, -1e-6)
    u = jax.random.normal(ks[4], (H, K)) * 0.5
    y_k, s_k = wkv6_pallas(r, k, v, w, u, chunk=16)
    y_c, s_c = wkv6_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_c),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- model integration
def test_rwkv_model_uses_pallas_path():
    """use_pallas=True end to end through the rwkv6 model forward."""
    from repro.configs import get_config
    from repro.models import transformer as tfm
    cfg = get_config("rwkv6-1.6b").reduced()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size, jnp.int32)
    x_ref, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False,
                              use_pallas=False)
    x_pal, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False,
                              use_pallas=True)
    np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_ref),
                               rtol=2e-3, atol=2e-3)


def test_mamba_model_uses_pallas_path():
    from repro.configs import get_config
    from repro.models import transformer as tfm
    cfg = get_config("zamba2-2.7b").reduced()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size, jnp.int32)
    x_ref, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False,
                              use_pallas=False)
    x_pal, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False,
                              use_pallas=True)
    np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_ref),
                               rtol=2e-3, atol=2e-3)
