"""Training-runtime integration: convergence, crash-recovery, checkpointing,
grad compression, straggler monitor, data-pipeline seekability."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import make_source
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.parallel.collectives import compress_tree, compressed_psum
from repro.runtime.trainer import StragglerMonitor, TrainConfig, train


def tiny_cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("granite-3-2b").reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=128)


def test_data_pipeline_is_seekable():
    src = make_source("markov", 128, 32, 4, seed=7)
    a = src.batch_at(11)
    src2 = make_source("markov", 128, 32, 4, seed=7)
    b = src2.batch_at(11)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(src.batch_at(11), src.batch_at(12))


@pytest.mark.slow
def test_loss_decreases_on_learnable_data(tmp_path):
    cfg = tiny_cfg()
    tc = TrainConfig(steps=80, batch=8, seq_len=32, ckpt_every=1000,
                     ckpt_dir=str(tmp_path / "c1"), log_every=0,
                     opt=adamw.OptConfig(lr=5e-3, warmup_steps=10,
                                         total_steps=80))
    _, _, hist = train(cfg, tc)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_crash_recovery_resumes_bit_identically(tmp_path):
    cfg = tiny_cfg()

    def tc(d):
        return TrainConfig(steps=12, batch=4, seq_len=32, ckpt_every=4,
                           ckpt_dir=str(d), log_every=0,
                           opt=adamw.OptConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=12))

    # uninterrupted run
    pA, _, histA = train(cfg, tc(tmp_path / "a"))
    # crashed run: stop after 6 steps (mid-interval), then resume
    train(cfg, tc(tmp_path / "b"), stop_after=6)
    assert store.latest_valid_step(str(tmp_path / "b")) == 4
    pB, _, histB = train(cfg, tc(tmp_path / "b"))
    # identical final params (data pipeline is seekable; ckpt is exact)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_validated(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16)}
    store.save(d, 1, tree)
    store.save(d, 2, tree)
    # corrupt step_2's payload -> restore must fall back to step_1
    with open(os.path.join(d, "step_2", "arrays.npz"), "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")
    assert store.latest_valid_step(d) == 1
    got = store.restore(d, 1, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    assert got["b"].dtype == np.asarray(jax.device_get(tree["b"])).dtype


def test_checkpoint_gc_keeps_n(tmp_path):
    d = str(tmp_path / "gc")
    tree = {"x": jnp.zeros((2,))}
    for s in range(1, 6):
        store.save(d, s, tree, keep=2)
    assert sorted(store.all_steps(d)) == [4, 5]


def test_compressed_psum_error_feedback_converges():
    """Error feedback: the *accumulated* quantized stream tracks the true
    stream; per-step error stays bounded instead of growing."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    r = jnp.zeros_like(g_true)
    acc_q = jnp.zeros_like(g_true)
    for _ in range(50):
        q, r = compressed_psum(g_true, r, axis=None)
        acc_q = acc_q + q
    # mean of quantized stream ~= true gradient to quantization precision
    np.testing.assert_allclose(np.asarray(acc_q / 50), np.asarray(g_true),
                               atol=2e-3)


@pytest.mark.slow
def test_training_with_compression_converges(tmp_path):
    cfg = tiny_cfg()
    tc = TrainConfig(steps=80, batch=8, seq_len=32, ckpt_every=1000,
                     ckpt_dir=str(tmp_path / "cc"), log_every=0,
                     opt=adamw.OptConfig(lr=5e-3, warmup_steps=10,
                                         total_steps=80,
                                         compress_grads=True))
    _, _, hist = train(cfg, tc)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.3, (first, last)


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(factor=3.0)
    flags = [m.observe(dt) for dt in
             [1.0, 1.1, 0.9, 1.0, 5.0, 1.0, 1.05]]
    assert flags == [False, False, False, False, True, False, False]
    assert m.flags == 1
    assert m.ewma < 1.5  # the straggler did not poison the baseline


@pytest.mark.slow
def test_gradient_accumulation_matches_full_batch(tmp_path):
    cfg = tiny_cfg()
    src = make_source("markov", cfg.vocab_size, 32, 8, seed=1)
    batch = {"tokens": jnp.asarray(src.batch_at(0))}
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    oc = adamw.OptConfig(lr=1e-3)
    from repro.runtime.trainer import make_train_step
    tc1 = TrainConfig(microbatches=1, opt=oc, remat=False)
    tc2 = TrainConfig(microbatches=4, opt=oc, remat=False)
    p1, _, m1 = jax.jit(make_train_step(cfg, tc1))(
        params, adamw.init(params, oc), batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, tc2))(
        params, adamw.init(params, oc), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_elastic_restore_shape_agnostic(tmp_path):
    """Checkpoints are logical arrays: restore works regardless of the mesh
    that wrote them (here: write plain, restore with explicit sharding onto
    the 1-device 'mesh')."""
    d = str(tmp_path / "el")
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
    store.save(d, 3, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got = store.restore(d, 3, tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
