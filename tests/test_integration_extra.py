"""Cross-layer integration: kernels vs engine semantics, embedding overflow
telemetry, elastic restore across device counts."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import LocalComm
from repro.core.routing import route_tasks
from repro.kernels.scatter_update.kernel import scatter_segments


def test_routed_updates_feed_scatter_kernel():
    """The engine's T3 fold == the Pallas scatter kernel on the same binned
    updates (the kernel is the TPU hot-spot version of the same step)."""
    T, chunk, cap = 4, 64, 32
    rng = np.random.default_rng(0)
    n = 24
    idx = rng.integers(0, T * chunk, (T, n))
    vals = rng.normal(size=(T, n)).astype(np.float32)
    msgs = jnp.stack([jnp.asarray(idx, jnp.int32),
                      jax.lax.bitcast_convert_type(
                          jnp.asarray(vals), jnp.int32)], axis=2)
    dest = jnp.asarray(idx // chunk, jnp.int32)
    comm = LocalComm(T)
    r = route_tasks(comm, msgs, jnp.ones((T, n), bool), dest, cap)
    # per-device binned updates -> local indices
    recv_idx = np.asarray(r.recv[..., 0])
    recv_val = np.asarray(
        jax.lax.bitcast_convert_type(r.recv[..., 1], jnp.float32))
    local_idx = np.where(np.asarray(r.recv_valid), recv_idx % chunk, -1)
    base = rng.normal(size=(T, chunk)).astype(np.float32)
    got = np.asarray(scatter_segments(
        jnp.asarray(base), jnp.asarray(local_idx, jnp.int32),
        jnp.asarray(recv_val), op="min"))
    # oracle: apply all (sent) updates directly
    expect = base.copy()
    spillv = np.asarray(r.spill_valid)
    for t in range(T):
        for i in range(n):
            if not spillv[t, i]:
                d, l = idx[t, i] // chunk, idx[t, i] % chunk
                expect[d, l] = min(expect[d, l], vals[t, i])
    np.testing.assert_allclose(got, expect, rtol=1e-6, atol=1e-6)


def test_embedding_overflow_counter():
    """Capacity starvation is counted, not silent (single-device path uses
    plain gather, so test the routed slot math directly)."""
    from repro.core.embedding import _routed_lookup_local

    # emulate one shard of M=1 so the all_to_all is the identity
    class FakeAxis:
        pass
    # _routed_lookup_local needs an axis; run under a 1-device shard_map
    from repro.launch.mesh import auto_mesh
    mesh = auto_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P
    table = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    ids = jnp.zeros((6,), jnp.int32)  # all hit row 0 -> overflow beyond cap

    def body(t, i):
        return _routed_lookup_local(t, i, capacity=2, axis="model", M=1)

    from repro.core.comm import shard_map_compat
    emb, ovf = jax.jit(shard_map_compat(
        body, mesh=mesh, in_specs=(P(None, None), P(None)),
        out_specs=(P(None, None), P())))(table, ids)
    assert int(ovf) == 4  # 6 lookups, capacity 2
    np.testing.assert_allclose(np.asarray(emb[:2]),
                               np.asarray(table[:1]).repeat(2, 0))
    assert (np.asarray(emb[2:]) == 0).all()  # overflowed rows zero-filled


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import sys, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import store

mode, d = sys.argv[1], sys.argv[2]
from repro.launch.mesh import auto_mesh
mesh = auto_mesh((len(jax.devices()),), ("data",))
tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
sh = {"w": NamedSharding(mesh, P("data", None))}
if mode == "save":
    t = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sh)
    store.save(d, 1, t)
    print("SAVED", len(jax.devices()))
else:
    got = store.restore(d, 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    print("RESTORED", len(jax.devices()))
"""


@pytest.mark.slow
def test_elastic_restore_across_device_counts(tmp_path):
    """Checkpoint written on an 8-device mesh restores onto a 2-device mesh
    (the elastic re-scale path)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    d = str(tmp_path / "elastic")
    for ndev, mode, expect in ((8, "save", "SAVED 8"),
                               (2, "restore", "RESTORED 2")):
        out = subprocess.run(
            [sys.executable, "-c", ELASTIC_SCRIPT % ndev, mode, d],
            env=env, capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-3000:]
        assert expect in out.stdout
