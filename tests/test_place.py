"""Migration invariance (repro.place): a plan is a pure relabeling.

The load-bearing contract of telemetry-driven adaptive placement: applying
a migration plan must not change what any workload computes — only WHERE
each vertex's work runs.  Concretely, three nested guarantees, each tested
here:

* **Structural** — ``apply_plan(pg, plan)`` is bitwise identical (every
  shard array, and every ``Stats`` field of a run on it, including the
  flits-per-class totals) to a partition *built from scratch* with the
  composed placement.  This is the strongest form of "conservation
  counters match": the migrated run IS the composed-placement run.
* **Value invariance vs the unmigrated twin** — converged values mapped
  back to original vertex ids are bit-identical across migration for every
  workload whose per-vertex arithmetic is order-independent (bfs / wcc /
  kcore: integer-valued f32; sssp: min over per-path ordered sums; spmv on
  integer instances; pagerank on dyadic instances — pow2-trimmed degrees,
  damping 1/2, V a power of two, inside the f32-exact epoch horizon), and
  total-count invariant for triangles (per-vertex attribution keys on
  *placed* order by design).
* **Counter conservation vs the unmigrated twin** — for the deterministic
  full-scan apps (spmv / pagerank / kcore) the placement-independent
  counters (``edges_scanned``, ``updates_applied``, delivered update
  messages) match exactly.  Traffic-class splits legitimately differ —
  that is the entire point of moving vertices — which is why the
  flits-per-class conservation claim lives in the structural contract
  above, not here.

Both execution backends (xla / pallas), both comm paths (LocalComm here,
shard_map in the slow subprocess test), and the serving lanes are covered;
``hypothesis`` fuzz rides on top when the dev extra is installed
(requirements-dev.txt), with deterministic seed-derived plans either way.
"""
import dataclasses
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, build_partition, rmat_edges
from repro.place import (MigrationPlan, adaptive_pagerank, apply_plan,
                         migration_plan, migration_words, price_migration,
                         remap_state, swap_permutation, validate_plan)

pytestmark = pytest.mark.place

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # dev extra (requirements-dev.txt)
    HAVE_HYPOTHESIS = False


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=4096,
                max_rounds=5000)
    base.update(kw)
    return EngineConfig(**base)


T = 8


@pytest.fixture(scope="module")
def graph():
    # unit weights: spmv / pagerank instances below stay exactly
    # representable, so cross-placement sums can be compared bitwise
    n, src, dst, _ = rmat_edges(7, edge_factor=5, seed=3)
    return CSRGraph.from_edges(n, src, dst, None)


@pytest.fixture(scope="module")
def gsym(graph):
    return alg.symmetrize(graph)


@pytest.fixture(scope="module")
def pg(graph):
    return alg.prepare(graph, T)


@pytest.fixture(scope="module")
def pgsym(gsym):
    return alg.prepare(gsym, T)


def _root(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


def random_plan(pg, seed: int, n_pairs: int = 8) -> MigrationPlan:
    """A deterministic random plan: disjoint slot pairs drawn by seed."""
    rng = np.random.default_rng(seed)
    n = min(n_pairs, len(pg.inv) // 2)
    slots = rng.choice(len(pg.inv), 2 * n, replace=False)
    return MigrationPlan(pairs=slots.reshape(n, 2).astype(np.int64))


def composed_partition(g, pg, plan, tile_die=None):
    """The from-scratch twin: build_partition on the composed placement."""
    perm = swap_permutation(len(pg.inv), plan.pairs)
    inv_new = np.empty_like(pg.inv)
    inv_new[perm] = pg.inv
    return build_partition(g, pg.T, perm[pg.place], inv_new, pg.edge_mode,
                           tile_die=tile_die)


def assert_stats_identical(a, b, note=""):
    for name, x, y in zip(type(a)._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"Stats.{name} {note}")


# --------------------------------------------------------------------------
# Plan machinery: permutations, validation, budget, die-awareness.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_swap_permutation_is_involution(pg, seed):
    plan = random_plan(pg, seed)
    perm = swap_permutation(len(pg.inv), plan.pairs)
    np.testing.assert_array_equal(perm[perm], np.arange(len(pg.inv)))
    touched = np.zeros(len(pg.inv), bool)
    touched[plan.pairs.reshape(-1)] = True
    np.testing.assert_array_equal(perm[~touched],
                                  np.arange(len(pg.inv))[~touched])


def test_validate_plan_rejects_malformed(pg):
    validate_plan(pg, random_plan(pg, 0))  # sanity: good plans pass
    with pytest.raises(ValueError, match="disjoint"):
        validate_plan(pg, MigrationPlan(
            pairs=np.array([[0, 1], [1, 2]], np.int64)))
    with pytest.raises(ValueError, match="self-swap"):
        validate_plan(pg, MigrationPlan(pairs=np.array([[3, 3]], np.int64)))
    with pytest.raises(ValueError, match="range"):
        validate_plan(pg, MigrationPlan(
            pairs=np.array([[0, len(pg.inv)]], np.int64)))


@pytest.mark.parametrize("seed,budget", [(0, 4), (1, 16), (2, 64), (3, 1)])
def test_migration_plan_valid_and_within_budget(pg, seed, budget):
    rng = np.random.default_rng(seed)
    busy = rng.uniform(1.0, 100.0, T)
    plan = migration_plan(pg, busy, budget=budget)
    validate_plan(pg, plan)
    assert plan.moved_vertices(pg) <= budget


def test_die_plan_reduces_cross_die_edges(graph):
    from repro.noc.topology import tile_die_map
    from repro.place import placed_edges
    td = tile_die_map(T, 0, 2, 1)
    pg0 = alg.prepare(graph, T, scheme="low_order_dielocal", dies=(2, 1))

    def cross(p):
        src, dst = placed_edges(p)
        die = td[np.arange(len(p.inv)) // p.v_chunk]
        return int((die[src] != die[dst]).sum())

    plan = migration_plan(pg0, None, budget=32, tile_die=td)
    assert "die" in plan.reason, "planner found no affinity candidates?"
    # phase B never crosses dies: every 'bal' pair stays on one die
    for (a, b), why in zip(plan.pairs, plan.reason):
        if why == "bal":
            assert td[a // pg0.v_chunk] == td[b // pg0.v_chunk]
    pg1 = apply_plan(graph, pg0, plan, tile_die=td)
    assert cross(pg1) < cross(pg0)


# --------------------------------------------------------------------------
# The structural contract: migrated == composed, bit for bit.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("edge_mode", ["equal_edges", "vertex_aligned"])
def test_apply_plan_is_pure_relabeling(graph, edge_mode, seed=5):
    pg0 = alg.prepare(graph, T, edge_mode=edge_mode)
    plan = random_plan(pg0, seed)
    a = apply_plan(graph, pg0, plan)
    b = composed_partition(graph, pg0, plan)
    for f in ("ptr_start", "deg", "edge_dst", "edge_val", "place", "inv"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{edge_mode}.{f}")


def test_run_on_migrated_equals_composed(graph, pg):
    """All Stats — total msgs, flits-per-class sums, every counter — of a
    run on the migrated partition equal the composed-placement run's."""
    plan = random_plan(pg, seed=6)
    r_mig = alg.bfs(apply_plan(graph, pg, plan), _root(graph), small_cfg())
    r_cmp = alg.bfs(composed_partition(graph, pg, plan), _root(graph),
                    small_cfg())
    np.testing.assert_array_equal(r_mig.values, r_cmp.values)
    assert_stats_identical(r_mig.stats, r_cmp.stats, "(migrated/composed)")


def test_sorted_adj_restored_for_triangles(gsym):
    pg0 = alg.prepare_triangles(gsym, T)
    plan = random_plan(pg0, seed=7, n_pairs=4)
    pg1 = apply_plan(gsym, pg0, plan)
    assert pg1.sorted_adj and pg1.edge_mode == "vertex_aligned"
    r0 = alg.triangles(pg0, small_cfg())
    r1 = alg.triangles(pg1, small_cfg())
    # per-vertex attribution keys on PLACED order (each triangle charged
    # to its placed-minimum corner), so only the total is invariant
    assert r0.values.sum() == r1.values.sum() > 0


# --------------------------------------------------------------------------
# Value invariance vs the unmigrated twin (all 7 workloads).
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["async", "bsp"])
@pytest.mark.parametrize("seed", [0, 1])
def test_bfs_invariant(graph, pg, mode, seed):
    pg1 = apply_plan(graph, pg, random_plan(pg, seed))
    cfg = small_cfg(mode=mode)
    r0 = alg.bfs(pg, _root(graph), cfg)
    r1 = alg.bfs(pg1, _root(graph), cfg)
    np.testing.assert_array_equal(r0.values, r1.values)
    np.testing.assert_array_equal(r0.values, ref.bfs_ref(graph,
                                                         _root(graph)))


@pytest.mark.parametrize("seed", [0, 1])
def test_sssp_invariant(graph, pg, seed):
    # min over paths of ordered per-path sums: placement-independent even
    # in f32 (each path's sum is computed in the same order either way)
    pg1 = apply_plan(graph, pg, random_plan(pg, seed))
    r0 = alg.sssp(pg, _root(graph), small_cfg())
    r1 = alg.sssp(pg1, _root(graph), small_cfg())
    np.testing.assert_array_equal(r0.values, r1.values)


@pytest.mark.parametrize("seed", [0, 1])
def test_wcc_invariant(gsym, pgsym, seed):
    pg1 = apply_plan(gsym, pgsym, random_plan(pgsym, seed))
    r0 = alg.wcc(pgsym, small_cfg())
    r1 = alg.wcc(pg1, small_cfg())
    np.testing.assert_array_equal(r0.values, r1.values)
    np.testing.assert_array_equal(r0.values, ref.wcc_ref(gsym))


@pytest.mark.parametrize("seed", [0, 1])
def test_kcore_invariant_with_counters(gsym, pgsym, seed):
    pg1 = apply_plan(gsym, pgsym, random_plan(pgsym, seed))
    r0 = alg.kcore(pgsym, 3, small_cfg())
    r1 = alg.kcore(pg1, 3, small_cfg())
    np.testing.assert_array_equal(r0.values, r1.values)
    _assert_counters_conserved(r0.stats, r1.stats)


@pytest.mark.parametrize("seed", [0, 1])
def test_spmv_invariant_with_counters(graph, pg, seed):
    # integer instance (unit weights x small-integer x): every partial sum
    # is exactly representable, so the y vector is placement-independent
    # bitwise despite the placement-dependent fold order
    x = np.random.default_rng(0).integers(0, 8, graph.num_vertices)
    pg1 = apply_plan(graph, pg, random_plan(pg, seed))
    r0 = alg.spmv(pg, x, small_cfg())
    r1 = alg.spmv(pg1, x, small_cfg())
    np.testing.assert_array_equal(r0.values, r1.values)
    _assert_counters_conserved(r0.stats, r1.stats)


def _pow2_degree_graph(g: CSRGraph) -> CSRGraph:
    """Trim each vertex's out-edges to the largest power of two <= deg:
    with damping 1/2 and V = 2^k every pagerank epoch is dyadic
    arithmetic, hence fold-order independent while numerators fit f32."""
    deg = g.ptr[1:] - g.ptr[:-1]
    keep = np.zeros(g.num_edges, bool)
    for v in range(g.num_vertices):
        d = int(deg[v])
        if d:
            keep[g.ptr[v]:g.ptr[v] + (1 << (d.bit_length() - 1))] = True
    src = np.repeat(np.arange(g.num_vertices), deg)[keep]
    return CSRGraph.from_edges(g.num_vertices, src, g.dst[keep],
                               np.ones(int(keep.sum()), np.float32),
                               dedup=False)


@pytest.mark.parametrize("seed", [0, 1])
def test_pagerank_dyadic_bitwise_and_general_close(graph, seed):
    gd = _pow2_degree_graph(graph)
    pgd = alg.prepare(gd, T)
    pg1 = apply_plan(gd, pgd, random_plan(pgd, seed))
    # dyadic instance, inside the f32-exact epoch horizon: bitwise
    r0 = alg.pagerank(pgd, damping=0.5, iters=2, cfg=small_cfg())
    r1 = alg.pagerank(pg1, damping=0.5, iters=2, cfg=small_cfg())
    np.testing.assert_array_equal(r0.values, r1.values)
    _assert_counters_conserved(r0.stats, r1.stats)
    # general instance: float-tolerance values, exact counters
    g0 = alg.prepare(graph, T)
    g1 = apply_plan(graph, g0, random_plan(g0, seed))
    a0 = alg.pagerank(g0, iters=4, cfg=small_cfg())
    a1 = alg.pagerank(g1, iters=4, cfg=small_cfg())
    np.testing.assert_allclose(a0.values, a1.values, rtol=1e-6, atol=1e-12)
    _assert_counters_conserved(a0.stats, a1.stats)


def _assert_counters_conserved(s0, s1):
    """The placement-independent counters of the deterministic full-scan
    apps: every edge is scanned and every update delivered exactly once
    per epoch regardless of who owns what (range-channel msgs are NOT
    conserved — chunk borders move with the placement)."""
    assert int(s0.edges_scanned) == int(s1.edges_scanned)
    assert int(s0.updates_applied) == int(s1.updates_applied)
    assert int(np.asarray(s0.msgs)[-1]) == int(np.asarray(s1.msgs)[-1])


# --------------------------------------------------------------------------
# Backends and comm paths.
# --------------------------------------------------------------------------

@pytest.mark.pallas
def test_bfs_invariant_pallas(graph, pg):
    pg1 = apply_plan(graph, pg, random_plan(pg, seed=2))
    cfg = small_cfg(backend="pallas")
    r0 = alg.bfs(pg, _root(graph), cfg)
    r1 = alg.bfs(pg1, _root(graph), cfg)
    np.testing.assert_array_equal(r0.values, r1.values)


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges
    from repro.place import MigrationPlan, apply_plan

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, _ = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, None)
    pg = alg.prepare(g, T=8)
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000)
    rng = np.random.default_rng(11)
    slots = rng.choice(len(pg.inv), 16, replace=False)
    plan = MigrationPlan(pairs=slots.reshape(8, 2).astype(np.int64))
    pg1 = apply_plan(g, pg, plan)

    base = alg.bfs(pg, root, cfg)                   # unmigrated, LocalComm
    spmd = alg.bfs(pg1, root, cfg, mesh=mesh)       # migrated, shard_map
    loc = alg.bfs(pg1, root, cfg)                   # migrated, LocalComm
    np.testing.assert_array_equal(base.values, spmd.values)
    np.testing.assert_array_equal(loc.values, spmd.values)
    for f, a, b in zip(type(loc.stats)._fields, loc.stats, spmd.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="Stats." + f)
    print("SPMD-PLACE-OK")
""")


@pytest.mark.slow
def test_migration_invariance_spmd_subprocess():
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SPMD-PLACE-OK" in r.stdout


# --------------------------------------------------------------------------
# Serving lanes: between-batch adaptation keeps every query exact.
# --------------------------------------------------------------------------

def test_serving_adaptation_exact(graph, pg):
    from repro.serve import Frontend
    deg = np.asarray(graph.ptr[1:] - graph.ptr[:-1])
    srcs = np.flatnonzero(deg > 0)[:6].tolist()
    cfg = small_cfg(adapt=True, adapt_every=1, adapt_budget=16)
    fe = Frontend(pg, app="bfs", cfg=cfg, width=2, graph=graph)
    rep = fe.serve(srcs)
    assert rep.migrated_vertices > 0, "adaptation never fired"
    assert rep.drops == 0
    # every query — served before AND after the mid-stream migrations —
    # bit-identical to its solo run on the original partition
    for rec in rep.records:
        np.testing.assert_array_equal(
            rec.values, alg.bfs(pg, rec.source, small_cfg()).values)
    assert rep.row()["migrated_vertices"] == rep.migrated_vertices
    # additive: non-adaptive reports keep their historical row shape
    rep0 = Frontend(pg, app="bfs", cfg=small_cfg(), width=2).serve(srcs)
    assert "migrated_vertices" not in rep0.row()


def test_serving_adaptation_guards(pg, graph):
    from repro.serve import Frontend
    with pytest.raises(ValueError, match="graph"):
        Frontend(pg, app="bfs", cfg=small_cfg(adapt=True), width=2)
    with pytest.raises(ValueError, match="static"):
        Frontend(pg, app="bfs", cfg=small_cfg(adapt=True), width=2,
                 policy="continuous", graph=graph)


# --------------------------------------------------------------------------
# Pricing, state remap, and the epoch-boundary driver.
# --------------------------------------------------------------------------

def test_price_migration_counters_and_energy_oracle(graph, pg):
    from repro.noc.network import make_network
    from repro.perf.model import energy_from_totals
    cfg = small_cfg()
    plan = random_plan(pg, seed=8)
    res = alg.bfs(apply_plan(graph, pg, plan), _root(graph), cfg)
    s0 = res.stats
    s1 = price_migration(s0, pg, plan, T, params=cfg.perf)
    moved = plan.moved_vertices(pg)
    wi, wc = migration_words(pg, plan)
    assert wc == 0  # no tile_die given: every move priced intra-die
    assert int(s1.migrated_vertices) == moved > 0
    assert float(s1.migration_cycles) > 0
    assert float(s1.cycles) > float(s0.cycles)
    net = make_network(cfg, T)
    # the oracle recomputes energy from counters (incl. migration_pj and
    # leakage over the now-larger cycle total) — pricing must keep it true
    want = energy_from_totals(s1, cfg.perf, net, T)
    np.testing.assert_allclose(float(s1.energy_pj), want, rtol=1e-5)


def test_remap_state_roundtrip(graph, pg):
    plan = random_plan(pg, seed=9)
    pg1 = apply_plan(graph, pg, plan)
    rng = np.random.default_rng(0)
    arr = np.where(pg.inv >= 0, rng.normal(size=len(pg.inv)),
                   0.0).astype(np.float32).reshape(pg.T, pg.v_chunk)
    fwd = remap_state(pg, pg1, arr)
    back = remap_state(pg1, pg, fwd)
    np.testing.assert_array_equal(back, arr)
    # original-id view unchanged by the remap
    np.testing.assert_array_equal(alg.to_original(pg, arr),
                                  alg.to_original(pg1, fwd))


def test_adaptive_pagerank_dyadic_bitwise(graph):
    gd = _pow2_degree_graph(graph)
    pgd = alg.prepare(gd, T)
    cfg = small_cfg(adapt=True, adapt_every=1, adapt_budget=16, trace=True,
                    trace_rounds=256)
    res, pg_final, plans = adaptive_pagerank(gd, pgd, damping=0.5, iters=3,
                                             cfg=cfg)
    twin = alg.pagerank(pgd, damping=0.5, iters=3,
                        cfg=small_cfg(trace=True, trace_rounds=256))
    assert plans and not np.array_equal(pg_final.place, pgd.place)
    np.testing.assert_array_equal(res.values, twin.values)
    assert int(res.stats.migrated_vertices) > 0
    assert float(res.stats.migration_cycles) > 0


# --------------------------------------------------------------------------
# Hypothesis fuzz (dev extra): the same properties, adversarial plans.
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2**31 - 1), budget=st.integers(0, 256))
    def test_fuzz_plan_validity(graph, seed, budget):
        pg0 = alg.prepare(graph, T)
        busy = np.random.default_rng(seed).uniform(0.0, 100.0, T)
        plan = migration_plan(pg0, busy, budget=budget)
        validate_plan(pg0, plan)
        assert plan.moved_vertices(pg0) <= budget

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2**31 - 1), n_pairs=st.integers(1, 24))
    def test_fuzz_bfs_invariance(graph, seed, n_pairs):
        # equal_edges keeps e_chunk fixed across plans: every drawn
        # example reuses the same compiled engine
        pg0 = alg.prepare(graph, T)
        plan = random_plan(pg0, seed, n_pairs)
        r0 = alg.bfs(pg0, _root(graph), small_cfg())
        r1 = alg.bfs(apply_plan(graph, pg0, plan), _root(graph),
                     small_cfg())
        np.testing.assert_array_equal(r0.values, r1.values)
