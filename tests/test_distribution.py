"""Property tests for placement/distribution invariants + dry-run helpers."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.distribution import DistSpec, placement, padded_len
from repro.launch.hloparse import collective_bytes, _shape_bytes


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 500), st.integers(1, 16), st.sampled_from(
    ["low_order", "high_order"]))
def test_placement_is_bijection(n, shards, scheme):
    place, inv = placement(n, shards, scheme)
    n_pad = padded_len(n, shards)
    assert len(place) == n
    assert len(inv) == n_pad
    # every original id maps to a unique slot, and inv inverts place
    assert len(set(place.tolist())) == n
    for v in range(min(n, 50)):
        assert inv[place[v]] == v
    # padding slots marked -1
    assert (inv == -1).sum() == n_pad - n


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16), st.integers(1, 64))
def test_distspec_owner_local_roundtrip(shards, chunk):
    spec = DistSpec(shards * chunk, shards)
    idx = np.arange(spec.total)
    owner = spec.owner(idx)
    local = spec.local(idx)
    assert (owner == idx // chunk).all()
    assert (spec.global_(owner, local) == idx).all()
    assert (local < chunk).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(1, 100))
def test_low_order_scatters_consecutive_ids(shards, base):
    """Consecutive (hot) vertex ids land on different shards — the paper's
    balance property for degree-sorted graphs."""
    n = shards * 8
    place, _ = placement(n, shards, "low_order")
    spec = DistSpec(padded_len(n, shards), shards)
    owners = spec.owner(place[: shards])
    assert len(set(np.asarray(owners).tolist())) == shards


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 8), st.integers(0, 6))
def test_degree_interleave_is_bijection(n, shards, seed):
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, 50, n)
    place, inv = placement(n, shards, "degree_interleave", deg=deg)
    n_pad = padded_len(n, shards)
    assert len(set(place.tolist())) == n
    for v in range(min(n, 50)):
        assert inv[place[v]] == v
    assert (inv == -1).sum() == n_pad - n


def test_degree_interleave_spreads_hubs_round_robin():
    """The T highest-degree vertices land on T different tiles, in rank
    order — the paper's degree-aware placement rung."""
    deg = np.array([5, 1, 9, 8, 0, 3, 7, 2])
    shards = 4
    place, _ = placement(8, shards, "degree_interleave", deg=deg)
    chunk = padded_len(8, shards) // shards
    hubs = np.argsort(-deg, kind="stable")[:shards]
    assert set((place[hubs] // chunk).tolist()) == set(range(shards))
    # top hub on tile 0's first slot, second hub on tile 1's first slot...
    assert (place[hubs] % chunk == 0).all()
    with pytest.raises(ValueError, match="needs deg"):
        placement(8, shards, "degree_interleave")


def test_hlo_shape_bytes():
    assert _shape_bytes("bf16[2,4096,8192]{2,1,0}") == 2 * 4096 * 8192 * 2
    assert _shape_bytes("f32[8]{0}") == 32
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[2,512]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = (f32[8]{0}, f32[4]{0}) all-reduce(%a, %b), to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %a2a = f32[16,4]{1,0} all-to-all(%z)
  %cp = bf16[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not = f32[9]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 2 * 512 * 2
    assert out["bytes"]["all-reduce"] == 32 + 16
    assert out["bytes"]["reduce-scatter"] == 256
    assert out["bytes"]["all-to-all"] == 256
    assert out["bytes"]["collective-permute"] == 8
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(out["bytes"].values())
