"""Multi-die hierarchical NoC (noc="hier") + die-local placement.

The invariants under test:

* hier line geometry: cross-die travel is local-to-gateway, DIE express
  hops, local-from-gateway; one die degenerates to the mesh/torus line;
* ``hier(ndies=1, base=mesh)`` is **bit-identical** to ``mesh`` — values
  and the full Stats tuple, telemetry and perf model included — on both
  execution backends (the acceptance anchor of the composition);
* die-crossing telemetry is exact on a fixed cross-die workload (one
  ``net.route`` round with hand-placed destinations);
* ``*_dielocal`` placements keep every partition's vertices on one die
  (and the die-aligned edge layout keeps its edges there too);
* on the fig8 workload, die-local placement strictly reduces DIE-class
  flits vs the flat scheme at ndies > 1;
* oracle correctness and drops == 0 hold under multi-die backpressure,
  intra-die torus base included.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.comm import LocalComm
from repro.core.distribution import placement
from repro.core.engine import EngineConfig, zero_stats
from repro.noc import (DIE_BWD, DIE_FWD, LOCAL_BWD, LOCAL_FWD, Hier2D,
                       Mesh2D, line_usage, make_network, tile_die_map)
from repro.noc.topology import CLASS_DIE, CLASS_WRAP, line_link_classes
from repro.perf import flits_by_class

from repro.core.graph import CSRGraph, rmat_edges


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=256, cap_updq=4096,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    # scale 7 / T=16 gives a 4x4 grid cuttable into 2x2 dies with
    # non-trivial cross-die traffic at tier-1 runtime cost
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=0)
    return CSRGraph.from_edges(n, src, dst, val)


def root_of(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


# --------------------------------------------------------------------------
# Line geometry and classes.
# --------------------------------------------------------------------------

def links(use, chan):
    return np.flatnonzero(np.asarray(use)[0, chan]).tolist()


def test_hier_line_cross_die_routes_via_gateways():
    # 1 -> 6 on an 8-line of 4-tile dies: local 1->3, express 3->7, local
    # 7->6 — the die-level journey completes before the final approach
    hops, use = line_usage(jnp.array([1]), jnp.array([6]), 8, die=4)
    assert int(hops[0]) == 4
    assert links(use, LOCAL_FWD) == [1, 2]
    assert links(use, DIE_FWD) == [3]
    assert links(use, LOCAL_BWD) == [7]
    # backward mirror 6 -> 1: local 6->4, express 4->0, local 0->1
    hops, use = line_usage(jnp.array([6]), jnp.array([1]), 8, die=4)
    assert int(hops[0]) == 4
    assert links(use, LOCAL_BWD) == [5, 6]
    assert links(use, DIE_BWD) == [4]
    assert links(use, LOCAL_FWD) == [0]
    # die-local travel is a plain mesh journey inside the segment
    hops, use = line_usage(jnp.array([5]), jnp.array([7]), 8, die=4)
    assert int(hops[0]) == 2 and links(use, LOCAL_FWD) == [5, 6]
    assert not np.asarray(use)[0, DIE_FWD].any()
    # three dies: one express hop per boundary (0 -> 10 on a 12-line:
    # local 0->3, express 3->7->11, local 11->10)
    hops, use = line_usage(jnp.array([0]), jnp.array([10]), 12, die=4)
    assert int(hops[0]) == 6
    assert links(use, LOCAL_FWD) == [0, 1, 2]
    assert links(use, DIE_FWD) == [3, 7]
    assert links(use, LOCAL_BWD) == [11]


def test_hier_line_one_die_is_the_flat_line():
    a = jnp.array([0, 5, 3, 7], jnp.int32)
    b = jnp.array([7, 2, 3, 0], jnp.int32)
    for wrap in (False, True):
        hm, um = line_usage(a, b, 8, wrap=wrap)
        hh, uh = line_usage(a, b, 8, wrap=wrap, die=8)
        np.testing.assert_array_equal(np.asarray(hm), np.asarray(hh))
        np.testing.assert_array_equal(np.asarray(um), np.asarray(uh))


def test_hier_line_classes_and_intra_die_wrap():
    cls = line_link_classes(8, die=4)
    assert (cls[DIE_FWD] == CLASS_DIE).all()
    assert (cls[DIE_BWD] == CLASS_DIE).all()
    assert not (cls == CLASS_WRAP).any()
    # torus base: every die closes its own ring
    cls = line_link_classes(8, wrap=True, die=4)
    assert np.flatnonzero(cls[LOCAL_FWD] == CLASS_WRAP).tolist() == [3, 7]
    assert np.flatnonzero(cls[LOCAL_BWD] == CLASS_WRAP).tolist() == [0, 4]
    # intra-die torus travel takes the shorter way inside the segment
    hops, use = line_usage(jnp.array([4]), jnp.array([7]), 8, wrap=True,
                           die=4)
    assert int(hops[0]) == 1 and links(use, LOCAL_BWD) == [4]


def test_tile_die_map_geometry():
    np.testing.assert_array_equal(
        tile_die_map(16, 0, 2, 2),
        [0, 0, 1, 1, 0, 0, 1, 1, 2, 2, 3, 3, 2, 2, 3, 3])
    np.testing.assert_array_equal(tile_die_map(8, 0, 2, 1),
                                  [0, 0, 0, 0, 1, 1, 1, 1])
    with pytest.raises(ValueError, match="not divisible"):
        tile_die_map(16, 0, 3, 1)


def test_make_network_builds_hier():
    net = make_network(small_cfg(noc="hier", ndies_x=2, ndies_y=2), 16)
    assert isinstance(net, Hier2D)
    assert (net.die_x, net.die_y) == (2, 2)
    assert net.max_die_crossings == 2
    assert (np.asarray(net.link_classes) == CLASS_DIE).sum() > 0
    with pytest.raises(ValueError, match="not divisible"):
        make_network(small_cfg(noc="hier", ndies_x=3), 16)
    with pytest.raises(ValueError, match="mesh|torus"):
        make_network(small_cfg(noc="hier", hier_base="ring"), 16)


# --------------------------------------------------------------------------
# ndies=1 equivalence: the composition anchor.
# --------------------------------------------------------------------------

def assert_stats_equal(a, b):
    for name in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=f"Stats.{name}")


def test_hier_one_die_bit_identical_to_mesh(g):
    root = root_of(g)
    pg = alg.prepare(g, T=16)
    rm = alg.bfs(pg, root, small_cfg(noc="mesh", link_cap=2))
    rh = alg.bfs(pg, root, small_cfg(noc="hier", link_cap=2))
    np.testing.assert_array_equal(rm.values, rh.values)
    assert_stats_equal(rm.stats, rh.stats)


@pytest.mark.pallas
def test_hier_one_die_bit_identical_to_mesh_on_pallas(g):
    root = root_of(g)
    pg = alg.prepare(g, T=16)
    rm = alg.bfs(pg, root, small_cfg(noc="mesh", link_cap=2,
                                     backend="pallas"))
    rh = alg.bfs(pg, root, small_cfg(noc="hier", link_cap=2,
                                     backend="pallas"))
    np.testing.assert_array_equal(rm.values, rh.values)
    assert_stats_equal(rm.stats, rh.stats)


def test_hier_one_die_torus_base_matches_torus_values(g):
    """Torus-base hier at one die wires every line as one wrapped ring;
    values and per-link flits match Torus2D (hop-histogram shapes differ
    by design: hier keeps the mesh-shaped bound)."""
    root = root_of(g)
    pg = alg.prepare(g, T=16)
    rt = alg.bfs(pg, root, small_cfg(noc="torus", link_cap=2))
    rh = alg.bfs(pg, root, small_cfg(noc="hier", hier_base="torus",
                                     link_cap=2))
    np.testing.assert_array_equal(rt.values, rh.values)
    np.testing.assert_array_equal(np.asarray(rt.stats.flits_per_link),
                                  np.asarray(rh.stats.flits_per_link))
    assert int(rh.stats.rounds) == int(rt.stats.rounds)


# --------------------------------------------------------------------------
# Die-crossing telemetry: exact on a fixed one-round workload.
# --------------------------------------------------------------------------

def test_die_crossing_counts_deterministic():
    """4x4 grid, 2x2 dies, uncapped links, ample endpoint capacity: one
    route round delivers everything, so die_hist and DIE-class flits are
    exact per message."""
    net = Hier2D(16, 4, 4, link_cap=0, ndies_x=2, ndies_y=2)
    chunk = 4
    # tile 0 (die 0) sends to: itself (0 crossings), tile 3 (die 1, one X
    # boundary), tile 12 (die 2, one Y), tile 15 (die 3, X + Y)
    dests = {0: [0, 3, 12, 15]}
    msgs = np.full((16, 4, 2), -1, np.int32)
    for t, ds in dests.items():
        for j, d in enumerate(ds):
            msgs[t, j] = (d * chunk, 7)  # head flit owned by tile d
    valid = jnp.asarray(msgs[..., 0] >= 0)
    r = net.route(LocalComm(16), jnp.asarray(msgs), valid, capacity=4,
                  dest_fn=lambda m: m[..., 0] // chunk)
    assert int(r.recv_valid.sum()) == 4 and int(r.spill_valid.sum()) == 0
    die_hist = np.asarray(r.die_hist).sum(0)
    np.testing.assert_array_equal(die_hist, [1, 2, 1])
    # DIE-link flits: one per boundary crossed = 1 + 1 + 2
    cls = np.asarray(net.link_classes)
    flits = np.asarray(r.link_flits).sum(0)
    assert flits[cls == CLASS_DIE].sum() == 4
    # hop conservation still holds: all flits ride some link exactly once
    hop = np.asarray(r.hop_hist).sum(0)
    assert flits.sum() == (hop * np.arange(len(hop))).sum()


def test_zero_stats_carries_die_hist_shape():
    z = zero_stats(small_cfg(noc="hier", ndies_x=2, ndies_y=2), 16)
    assert z.die_crossings.shape == (3,)
    z1 = zero_stats(small_cfg(noc="mesh"), 16)
    assert z1.die_crossings.shape == (1,)


# --------------------------------------------------------------------------
# Die-local placement.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["low_order_dielocal",
                                    "high_order_dielocal",
                                    "degree_interleave_dielocal"])
def test_dielocal_placement_keeps_partitions_die_resident(scheme):
    T, n = 16, 1000
    tdm = tile_die_map(T, 0, 2, 2)
    rng = np.random.default_rng(0)
    deg = rng.integers(0, 50, n)
    place, inv = placement(n, T, scheme, deg=deg, tile_die=tdm)
    # bijection over the padded space
    assert len(set(place.tolist())) == n
    assert (inv[place] == np.arange(n)).all()
    n_pad = len(inv)
    chunk = n_pad // T
    # every partition (contiguous quarter of the padded ID space) lands
    # entirely on the tiles of one die, in partition order
    sc = n_pad // 4
    tile_of = place // chunk
    np.testing.assert_array_equal(tdm[tile_of], np.arange(n) // sc)
    with pytest.raises(ValueError, match="needs tile_die"):
        placement(n, T, scheme, deg=deg)


def test_dielocal_edges_are_die_resident_too(g):
    """die_aligned mode: an edge chunk's owner tile is in the same die as
    the vertices whose edges it stores — range messages never cross."""
    pg = alg.prepare(g, T=16, scheme="low_order_dielocal", dies=(2, 2))
    assert pg.edge_mode == "die_aligned"
    tdm = tile_die_map(16, 0, 2, 2)
    sc = (pg.T * pg.v_chunk) // 4
    ptr = np.asarray(pg.ptr_start).reshape(-1)
    deg = np.asarray(pg.deg).reshape(-1)
    vert_tile = np.arange(pg.T).repeat(pg.v_chunk)
    for p in range(pg.T * pg.v_chunk):
        if deg[p] == 0:
            continue
        chunks = np.arange(ptr[p], ptr[p] + deg[p]) // pg.e_chunk
        assert (tdm[chunks] == tdm[vert_tile[p]]).all()


def test_dielocal_one_die_layout_equals_flat(g):
    a = alg.prepare(g, T=16)
    b = alg.prepare(g, T=16, scheme="low_order_dielocal", dies=(1, 1))
    np.testing.assert_array_equal(a.place, b.place)
    np.testing.assert_array_equal(np.asarray(a.ptr_start),
                                  np.asarray(b.ptr_start))
    np.testing.assert_array_equal(np.asarray(a.edge_dst),
                                  np.asarray(b.edge_dst))


def test_dielocal_strictly_reduces_die_flits(g):
    """The acceptance criterion: at ndies > 1, die-local placement
    strictly reduces DIE-class traffic vs the flat scheme on the same
    hier fabric (fig8's workload shape, tier-1 scale).  Uncapped links —
    the fig8-hier offered-load convention — so the comparison measures
    the placement's locality structure, not replay inflation."""
    root = root_of(g)
    cfg = small_cfg(noc="hier", ndies_x=2, ndies_y=2, link_cap=0)
    net = make_network(cfg, 16)
    want = ref.bfs_ref(g, root)
    flat = alg.bfs(alg.prepare(g, T=16), root, cfg)
    loc = alg.bfs(alg.prepare(g, T=16, scheme="low_order_dielocal",
                              dies=(2, 2)), root, cfg)
    np.testing.assert_array_equal(flat.values, want)
    np.testing.assert_array_equal(loc.values, want)
    assert int(flat.stats.drops) == 0 and int(loc.stats.drops) == 0
    die_flat = flits_by_class(flat.stats, net)["die"]
    die_loc = flits_by_class(loc.stats, net)["die"]
    assert die_loc < die_flat, (die_loc, die_flat)
    # and a strictly smaller fraction of injections cross a die at all
    fr = [np.asarray(r.stats.die_crossings) for r in (flat, loc)]
    frac = [h[1:].sum() / h.sum() for h in fr]
    assert frac[1] < frac[0], frac


@pytest.mark.slow
@pytest.mark.parametrize("ndies,T", [((3, 3), 36), ((4, 4), 64)])
def test_hier_large_die_arrays_values_and_crossing_conservation(g, ndies,
                                                                T):
    """ROADMAP carry-over: pin hier correctness beyond 2x2 — a 3x3 die
    array (6x6 grid of 2x2-tile dies) and a 4x4 array (8x8 grid of
    2x2-tile dies).  Values match the oracle with zero drops, and on
    uncapped links the die-crossing telemetry conserves exactly:

    * DIE-class flits == sum k * die_hist[k] (each injection that crosses
      k die boundaries rides exactly k DIE links);
    * die_hist.sum() == hop_hist.sum() (every injection is binned once in
      both histograms);
    * total flits == sum k * hop_hist[k] (every flit rides some link).
    """
    ny, nx = ndies
    root = root_of(g)
    want = ref.bfs_ref(g, root)
    # queue caps sized for the larger grids (worst-case inflow grows
    # with T; Program.validate enforces the bound)
    cfg = small_cfg(noc="hier", ndies_y=ny, ndies_x=nx, link_cap=0,
                    cap_rangeq=1024, cap_updq=16384)
    net = make_network(cfg, T)
    assert net.max_die_crossings == (ny - 1) + (nx - 1)
    pg = alg.prepare(g, T, scheme="low_order_dielocal", dies=ndies)
    res = alg.bfs(pg, root, cfg)
    np.testing.assert_array_equal(res.values, want)
    assert int(res.stats.drops) == 0
    die_hist = np.asarray(res.stats.die_crossings, np.int64)
    hop_hist = np.asarray(res.stats.hop_histogram, np.int64)
    flits = np.asarray(res.stats.flits_per_link, np.int64)
    cls = np.asarray(net.link_classes)
    assert die_hist[1:].sum() > 0  # the workload does cross dies
    assert flits[cls == CLASS_DIE].sum() == \
        (die_hist * np.arange(len(die_hist))).sum()
    assert die_hist.sum() == hop_hist.sum()
    assert flits.sum() == (hop_hist * np.arange(len(hop_hist))).sum()
    # and with finite links (spill/replay across the die gateways) the
    # oracle still holds, drop-free
    res2 = alg.bfs(pg, root, small_cfg(noc="hier", ndies_y=ny, ndies_x=nx,
                                       link_cap=2, cap_rangeq=1024,
                                       cap_updq=16384))
    np.testing.assert_array_equal(res2.values, want)
    assert int(res2.stats.drops) == 0


def test_hier_multi_die_matches_oracles_under_backpressure(g):
    """ndies=2x2 with link_cap=1 (heavy spill/replay across the scarce
    DIE links) still reproduces the oracle with zero drops, mesh and
    torus intra-die wirings alike."""
    root = root_of(g)
    pg = alg.prepare(g, T=16, scheme="low_order_dielocal", dies=(2, 2))
    for hier_base in ("mesh", "torus"):
        res = alg.bfs(pg, root, small_cfg(noc="hier", ndies_x=2, ndies_y=2,
                                          hier_base=hier_base, link_cap=1))
        np.testing.assert_array_equal(res.values, ref.bfs_ref(g, root))
        assert int(res.stats.drops) == 0
        assert int(np.asarray(res.stats.die_crossings)[1:].sum()) > 0
