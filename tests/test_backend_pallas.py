"""Pallas tile-grid backend == XLA backend, bit for bit.

Two layers of evidence (DESIGN.md "Pallas backend"):

* kernel-level — each :mod:`repro.kernels.engine` kernel against its XLA
  twin in ``core/program.py`` / ``core/queues.py`` over widths, ragged
  tails, empty frontiers, overflow, and duplicate indices (interpret mode);
* engine-level — ``EngineConfig(backend="pallas")`` against
  ``backend="xla"``: values AND the full Stats tuple (rounds, per-channel
  msgs/spills, cycles, energy_pj, link telemetry) must be equal.  Tier-1
  keeps a representative subset; the full seven-workloads x four-NoCs
  sweep and the shard_map SPMD twin run under ``-m slow`` (as CI does).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.core.program import Ctx, take_first_k
from repro.core.queues import queue_make, queue_push, queue_take_front
from repro.kernels.engine import (edge_scan_gather, fold_scatter,
                                  frontier_pop, queue_push_pop)

pytestmark = pytest.mark.pallas

INF32 = np.float32(np.finfo(np.float32).max)


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=4096,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


# --------------------------------------------------------------------------
# Kernel-level: each Pallas kernel vs its XLA twin.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,k_max", [
    (8, 3, 8), (32, 0, 8), (32, 8, 8),   # partial / zero / exact budget
    (257, 100, 16),                      # clamped to k_max (engine contract)
    (64, 5, 16), (16, 16, 16),           # odd width / full pop
])
def test_frontier_pop_matches_take_first_k(n, k, k_max):
    rng = np.random.default_rng(n * 31 + k)
    for density in (0.0, 0.3, 1.0):          # empty / sparse / full frontier
        mask = jnp.asarray(rng.random(n) < density)
        k_dyn = jnp.int32(min(k, k_max))
        i1, v1, m1 = take_first_k(mask, k_dyn, k_max)
        i2, v2, m2 = frontier_pop(mask, k_dyn, k_max)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        # idx agrees wherever valid; invalid slots are don't-cares
        np.testing.assert_array_equal(np.where(v1, i1, 0),
                                      np.where(v2, i2, 0))


def test_frontier_pop_vmapped_tile_grid():
    """Under vmap (LocalComm's per-tile stage), the batching rule turns the
    tile axis into the Pallas grid — per-tile results stay identical."""
    rng = np.random.default_rng(0)
    masks = jnp.asarray(rng.random((5, 48)) < 0.25)
    ks = jnp.asarray([0, 1, 4, 8, 8], jnp.int32)
    a = jax.vmap(lambda m, k: frontier_pop(m, k, 8))(masks, ks)
    b = jax.vmap(lambda m, k: take_first_k(m, k, 8))(masks, ks)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))


@pytest.mark.parametrize("cap,w,nrows,pop,max_n,prefill", [
    (16, 3, 8, 4, 6, 14),    # near-full: push overflows -> drops
    (8, 2, 8, 8, 8, 0),      # empty queue, pop the whole fresh batch
    (8, 2, 6, 3, 4, 7),      # ragged: pop less than occupancy
    (32, 4, 1, 0, 8, 3),     # zero pop budget (TSU throttled the channel)
])
def test_queue_push_pop_fuses_push_then_take_front(cap, w, nrows, pop,
                                                   max_n, prefill):
    rng = np.random.default_rng(cap * 7 + nrows)
    q = queue_make(cap, w)
    pre = jnp.asarray(rng.integers(0, 99, (cap, w)), jnp.int32)
    q, _ = queue_push(q, pre, jnp.arange(cap) < prefill)
    rows = jnp.asarray(rng.integers(0, 99, (nrows, w)), jnp.int32)
    valid = jnp.asarray(rng.random(nrows) < 0.7)
    q1, d1 = queue_push(q, rows, valid)
    t1, tv1, q1 = queue_take_front(q1, jnp.int32(pop), max_n)
    t2, tv2, ndata, ncount, d2 = queue_push_pop(
        q.data, q.count, rows, valid, jnp.int32(pop), max_n)
    # the engine feeds the FULL taken buffer to the channel transform, so
    # even the garbage rows beyond the pop count must match
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(tv1), np.asarray(tv2))
    assert int(d1) == int(d2)
    assert int(q1.count) == int(ncount)
    c = int(ncount)  # live rows identical; rows >= count are unobservable
    np.testing.assert_array_equal(np.asarray(q1.data)[:c],
                                  np.asarray(ndata)[:c])


@pytest.mark.parametrize("e_chunk,r,max_t2", [(64, 10, 8), (128, 1, 16),
                                              (33, 24, 4)])
def test_edge_scan_gather_matches_inline(e_chunk, r, max_t2):
    rng = np.random.default_rng(e_chunk + r)
    ed = jnp.asarray(rng.integers(-1, 100, e_chunk), jnp.int32)
    ev = jnp.asarray(rng.random(e_chunk), jnp.float32)
    start = jnp.asarray(rng.integers(0, 4 * e_chunk, r), jnp.int32)
    # ragged tails: lengths 0..max_t2, some rows invalid
    stop = start + jnp.asarray(rng.integers(0, max_t2 + 1, r), jnp.int32)
    rv = jnp.asarray(rng.random(r) < 0.75)
    nb, w, jv = edge_scan_gather(ed, ev, start, stop, rv, max_t2)
    length = jnp.where(rv, stop - start, 0)
    local0 = jnp.where(rv, start % e_chunk, 0)
    j = jnp.arange(max_t2, dtype=jnp.int32)[None, :]
    eidx = jnp.minimum(local0[:, None] + j, e_chunk - 1)
    jv_ref = rv[:, None] & (j < length[:, None])
    nb_ref = ed[eidx]
    np.testing.assert_array_equal(np.asarray(nb), np.asarray(nb_ref))
    np.testing.assert_array_equal(np.asarray(w), np.asarray(ev[eidx]))
    np.testing.assert_array_equal(np.asarray(jv),
                                  np.asarray(jv_ref & (nb_ref >= 0)))


@pytest.mark.parametrize("op", ["min", "add"])
@pytest.mark.parametrize("v_chunk,r", [(32, 20), (8, 64), (128, 1)])
def test_fold_scatter_matches_xla_twin(op, v_chunk, r):
    rng = np.random.default_rng(v_chunk * 3 + r)
    tgt = jnp.asarray(
        np.where(rng.random(v_chunk) < 0.3, INF32,  # "unreached" sentinels
                 rng.random(v_chunk).astype(np.float32)))
    # heavy duplicates + the v_chunk trash slot for invalid rows
    lidx_raw = jnp.asarray(rng.integers(0, max(v_chunk // 4, 1), r),
                           jnp.int32)
    valid = jnp.asarray(rng.random(r) < 0.6)
    lidx = jnp.where(valid, lidx_raw, v_chunk)
    vals = jnp.asarray(rng.normal(size=r), jnp.float32)
    from repro.core.program import scatter_fold
    ctx_x = Ctx(small_cfg(), 1, 1, v_chunk, "xla")
    ctx_p = Ctx(small_cfg(), 1, 1, v_chunk, "pallas")
    a = scatter_fold(ctx_x, tgt, lidx, vals, valid, op)
    b = scatter_fold(ctx_p, tgt, lidx, vals, valid, op)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fold_scatter_all_invalid_is_identity():
    tgt = jnp.asarray(np.float32([1.0, INF32, 3.0, 4.0]))
    lidx = jnp.full((6,), 4, jnp.int32)  # all trash
    out = fold_scatter(tgt, lidx, jnp.ones((6,), jnp.float32),
                       jnp.zeros((6,), bool), op="min")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(tgt))


# --------------------------------------------------------------------------
# Engine-level: backend="pallas" == backend="xla", full Stats tuple.
# --------------------------------------------------------------------------

def assert_stats_identical(a, b, where=""):
    for f, x, y in zip(a._fields, a, b):
        if f == "launches":
            continue  # launch accounting differs across backends by design
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"Stats.{f} differs between backends {where}")


@pytest.fixture(scope="module")
def g():
    n, src, dst, val = rmat_edges(6, edge_factor=5, seed=1)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)


def run_app(app, g, pg, cfg):
    if app == "bfs":
        root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
        return alg.bfs(pg, root, cfg)
    if app == "sssp":
        root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
        return alg.sssp(pg, root, cfg)
    if app == "spmv":
        x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
        return alg.spmv(pg, x, cfg)
    if app == "pagerank":
        return alg.pagerank(pg, iters=2, cfg=cfg)
    raise ValueError(app)


@pytest.mark.parametrize("app,noc", [("spmv", "ideal"), ("bfs", "torus")])
def test_backend_bit_identity_tier1(g, pg, app, noc):
    """Representative tier-1 corners: an add-fold single-epoch workload on
    the crossbar, and a min-fold relaxation on a wrapped physical NoC with
    finite links (spill/replay exercised on the pallas queue kernel)."""
    kw = dict(noc=noc, link_cap=2) if noc != "ideal" else dict(noc=noc)
    rx = run_app(app, g, pg, small_cfg(backend="xla", **kw))
    rp = run_app(app, g, pg, small_cfg(backend="pallas", **kw))
    np.testing.assert_array_equal(rx.values, rp.values)
    assert_stats_identical(rx.stats, rp.stats, f"({app}, {noc})")
    assert int(rp.stats.drops) == 0


def test_backend_empty_frontier(pg):
    """A root with no out-edges drains immediately on both backends."""
    g_iso = CSRGraph.from_edges(8, np.array([0]), np.array([1]),
                                np.ones(1, np.float32))
    pgi = alg.prepare(g_iso, T=4)
    rx = alg.bfs(pgi, 7, small_cfg(backend="xla"))
    rp = alg.bfs(pgi, 7, small_cfg(backend="pallas"))
    np.testing.assert_array_equal(rx.values, rp.values)
    assert_stats_identical(rx.stats, rp.stats, "(empty frontier)")


def test_per_channel_backend_hint_mixes_backends(g, pg):
    """A TaskSpec.backend="xla" pin on the fold channel under a global
    pallas config still matches the all-xla run bit for bit — mixed
    backends compose because every leg is bit-identical."""
    import dataclasses
    from repro.core.program import classic_program, BFS
    prog = classic_program(BFS)
    pinned = dataclasses.replace(
        prog, channels=(prog.channels[0],
                        dataclasses.replace(prog.channels[1],
                                            backend="xla")))
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    from repro.core.algorithms import init_min_state, local_engine_call
    value, frontier = init_min_state(pg, [root])
    vx, _, sx, _ = local_engine_call(pg, prog, small_cfg(backend="xla"),
                                     value, frontier)
    vm, _, sm, _ = local_engine_call(pg, pinned,
                                     small_cfg(backend="pallas"),
                                     value, frontier)
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(vm))
    assert_stats_identical(sx, sm, "(mixed backends)")


# --------------------------------------------------------------------------
# The full acceptance sweep: seven workloads x four NoCs (slow; CI runs it
# explicitly with -m slow, as for the other multi-minute suites).
# --------------------------------------------------------------------------

APPS = ("bfs", "sssp", "wcc", "spmv", "pagerank", "kcore", "triangles")
NOCS = ("ideal", "mesh", "torus", "ruche")


@pytest.mark.slow
@pytest.mark.parametrize("noc", NOCS)
def test_backend_bit_identity_full_sweep(g, noc):
    gs = alg.symmetrize(g)
    pg = alg.prepare(g, T=4)
    pgs = alg.prepare(gs, T=4)
    pgt = alg.prepare_triangles(gs, T=4)
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    kw = dict(noc=noc) if noc == "ideal" else dict(noc=noc, link_cap=2)
    cx, cp = small_cfg(backend="xla", **kw), small_cfg(backend="pallas",
                                                       **kw)
    x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
    runs = {
        "bfs": lambda c: alg.bfs(pg, root, c),
        "sssp": lambda c: alg.sssp(pg, root, c),
        "wcc": lambda c: alg.wcc(pgs, c),
        "spmv": lambda c: alg.spmv(pg, x, c),
        "pagerank": lambda c: alg.pagerank(pg, iters=2, cfg=c),
        "kcore": lambda c: alg.kcore(pgs, 2, c),
        "triangles": lambda c: alg.triangles(pgt, c),
    }
    for app in APPS:
        rx, rp = runs[app](cx), runs[app](cp)
        np.testing.assert_array_equal(rx.values, rp.values,
                                      err_msg=f"values ({app}, {noc})")
        assert_stats_identical(rx.stats, rp.stats, f"({app}, {noc})")
        assert int(rp.stats.drops) == 0


# --------------------------------------------------------------------------
# SPMD: the pallas backend under real shard_map (subprocess: multi-device
# CPU needs XLA_FLAGS before jax initializes).
# --------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core import reference as ref
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=8)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000,
                       backend="pallas")
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    r_spmd = alg.bfs(pg, root, cfg, mesh=mesh)
    r_local = alg.bfs(pg, root, cfg)
    np.testing.assert_array_equal(r_spmd.values, r_local.values)
    np.testing.assert_array_equal(r_spmd.values, ref.bfs_ref(g, root))
    assert int(r_spmd.stats.rounds) == int(r_local.stats.rounds)
    assert float(r_spmd.stats.cycles) == float(r_local.stats.cycles)
    assert float(r_spmd.stats.energy_pj) == float(r_local.stats.energy_pj)
    assert int(r_spmd.stats.drops) == 0
    print("SPMD-PALLAS-OK")
""")


@pytest.mark.slow
def test_spmd_pallas_backend_matches_local():
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr
    assert "SPMD-PALLAS-OK" in out.stdout
