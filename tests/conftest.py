"""Shared test fixtures / environment shims.

* Ensures ``src`` is importable even when PYTHONPATH wasn't set (CI and
  bare ``pytest`` runs behave the same as the documented tier-1 command).
* Lets the suite *collect* when optional dev deps (``hypothesis``) are
  missing: the property-test modules guard themselves with
  ``pytest.importorskip``, which needs collection to reach them instead of
  erroring at import — nothing here may import hypothesis eagerly.
"""
import os
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(SRC) and os.path.abspath(SRC) not in map(os.path.abspath,
                                                          sys.path):
    sys.path.insert(0, os.path.abspath(SRC))
