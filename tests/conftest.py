"""Shared test fixtures / environment shims.

* Ensures ``src`` is importable even when PYTHONPATH wasn't set (CI and
  bare ``pytest`` runs behave the same as the documented tier-1 command).
* Lets the suite *collect* when optional dev deps (``hypothesis``) are
  missing: the property-test modules guard themselves with
  ``pytest.importorskip``, which needs collection to reach them instead of
  erroring at import — nothing here may import hypothesis eagerly.
"""
import os
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(SRC) and os.path.abspath(SRC) not in map(os.path.abspath,
                                                          sys.path):
    sys.path.insert(0, os.path.abspath(SRC))

# The suite is XLA-compile dominated (every EngineConfig x program x graph
# shape is its own jit).  Persist compiled artifacts across runs so repeat
# tier-1 invocations skip recompilation; must be set via env BEFORE any
# test module imports jax (conftest runs first), and is inherited by the
# slow-marked multi-device subprocess tests.  Gated on compile time so the
# cache holds only the expensive engine/LM programs.
_CACHE = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                      ".jax_compilation_cache"))
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", _CACHE)
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1.0")
