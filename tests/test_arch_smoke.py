"""Per-architecture smoke tests: REDUCED config of each assigned family runs
one forward/train step on CPU; output shapes asserted, no NaNs; decode step
runs where the family has one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as tfm

ARCHS = list_archs()


def make_batch(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0,
                                          cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            ks[1], (B, cfg.num_patches, tfm.FRONTEND_DIM["vision"]),
            jnp.float32)
    elif cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, S, tfm.FRONTEND_DIM["audio"]), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: tfm.lm_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    assert int(metrics["overflow"]) == 0, arch
    x, _, _ = tfm.forward(params, cfg, batch, remat=False)
    B, S = batch["tokens"].shape
    S_total = S + (cfg.num_patches if cfg.frontend == "vision" else 0)
    assert x.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.isfinite(x).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_grads(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_params(jax.random.PRNGKey(2), cfg)
    batch = make_batch(cfg, B=1, S=16)
    grads = jax.jit(jax.grad(
        lambda p, b: tfm.lm_loss(p, cfg, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    # at least one nonzero grad per major group
    total = sum(float(jnp.abs(g).sum()) for g in flat)
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    B, C = 2, 16
    params = tfm.init_params(jax.random.PRNGKey(3), cfg)
    cache = tfm.init_cache(cfg, B, C)
    tok = jnp.array([[1], [2]], jnp.int32)
    step = jax.jit(lambda p, c, t: tfm.serve_step(p, cfg, c, t))
    nxt, cache = step(params, cache, tok)
    assert nxt.shape == (B,)
    assert int(cache.pos) == 1
    nxt2, cache = step(params, cache, nxt[:, None])
    assert int(cache.pos) == 2
    assert bool((nxt2 >= 0).all()) and bool((nxt2 < cfg.vocab_size + 16).all())


def test_decode_matches_forward_dense():
    """Teacher-forced decode == parallel forward (cache correctness)."""
    cfg = get_config("granite-3-2b").reduced()
    params = tfm.init_params(jax.random.PRNGKey(4), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    x_par, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False)
    # decode step by step
    cache = tfm.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        x1, cache, _ = tfm.forward(params, cfg,
                                   {"tokens": toks[:, t:t + 1]},
                                   cache=cache, remat=False)
        outs.append(x1[:, 0])
    x_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(x_par), np.asarray(x_seq),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow  # step-by-step eager decode, ~8s; dense stays in tier-1
def test_decode_matches_forward_swa():
    """Ring-buffer (sliding window) decode == windowed parallel forward."""
    cfg = get_config("mixtral-8x22b").reduced()
    assert cfg.sliding_window > 0
    params = tfm.init_params(jax.random.PRNGKey(6), cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    x_par, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False)
    C = min(S, cfg.sliding_window)
    cache = tfm.init_cache(cfg, B, C)
    outs = []
    for t in range(S):
        x1, cache, _ = tfm.forward(params, cfg,
                                   {"tokens": toks[:, t:t + 1]},
                                   cache=cache, remat=False)
        outs.append(x1[:, 0])
    x_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(x_par), np.asarray(x_seq),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow  # step-by-step eager decode; dense stays in tier-1
def test_decode_matches_forward_ssm():
    """RWKV state decode == parallel (chunked) forward."""
    cfg = get_config("rwkv6-1.6b").reduced()
    params = tfm.init_params(jax.random.PRNGKey(8), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    x_par, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False)
    cache = tfm.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        x1, cache, _ = tfm.forward(params, cfg,
                                   {"tokens": toks[:, t:t + 1]},
                                   cache=cache, remat=False)
        outs.append(x1[:, 0])
    x_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(x_par), np.asarray(x_seq),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.slow  # step-by-step eager decode; dense stays in tier-1
def test_decode_matches_forward_hybrid():
    cfg = get_config("zamba2-2.7b").reduced()
    params = tfm.init_params(jax.random.PRNGKey(10), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    x_par, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False)
    cache = tfm.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        x1, cache, _ = tfm.forward(params, cfg,
                                   {"tokens": toks[:, t:t + 1]},
                                   cache=cache, remat=False)
        outs.append(x1[:, 0])
    x_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(x_par), np.asarray(x_seq),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", [
    "granite-3-2b", "rwkv6-1.6b",
    # the two heaviest families decode eagerly for ~3s each; CI's -m slow
    # step keeps them covered
    pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
    pytest.param("mixtral-8x22b", marks=pytest.mark.slow)])
def test_prefill_then_decode_matches_parallel(arch):
    """prefill(prompt) + decode steps == one parallel forward."""
    cfg = get_config(arch).reduced()
    params = tfm.init_params(jax.random.PRNGKey(12), cfg)
    B, P, G = 1, 8, 3
    toks = jax.random.randint(jax.random.PRNGKey(13), (B, P + G), 0,
                              cfg.vocab_size, jnp.int32)
    x_par, _, _ = tfm.forward(params, cfg, {"tokens": toks}, remat=False)
    cache = tfm.init_cache(cfg, B, P + G)
    last, cache = tfm.prefill(params, cfg, cache,
                              {"tokens": toks[:, :P]})
    np.testing.assert_allclose(np.asarray(last), np.asarray(x_par[:, P - 1]),
                               rtol=5e-3, atol=5e-3)
    assert int(cache.pos) == P
    outs = []
    for t in range(P, P + G):
        x1, cache, _ = tfm.forward(params, cfg,
                                   {"tokens": toks[:, t:t + 1]},
                                   cache=cache, remat=False)
        outs.append(x1[:, 0])
    x_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(x_par[:, P:]), np.asarray(x_seq),
                               rtol=5e-3, atol=5e-3)
