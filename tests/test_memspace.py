"""Memory-space abstraction tests (PR8, DESIGN.md "Memory spaces").

The registry (``repro.mem``), the config-time budget validator, the
double-buffered HBM segment-DMA stream (``segment_stream``) and the
end-to-end space-equivalence contract: an HBM-streamed edge shard must be
bit-identical to the VMEM-resident run in values and in every Stats field
except the per-space counters and the per-space pricing.

Selected in tier-1 and also run as an explicit CI step via
``-m "memspace and not slow"``; the shard_map twin runs under ``-m slow``.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import reference as ref
from repro.core.engine import EngineConfig
from repro.core.graph import CSRGraph, rmat_edges
from repro.core.program import as_program, resolve_edge_space
from repro.kernels.engine import segment_gather, segment_stream
from repro.mem import (check_alloc, check_budgets, get_space, resolve_window,
                       space_budget)

pytestmark = pytest.mark.memspace

# Stats fields allowed to differ between a VMEM-resident and an
# HBM-streamed run of the same program: the per-space counters and the
# per-space pricing they feed (plus launches, which tracks backend
# dispatches, not the program).
SPACE_DEPENDENT = ("cycles", "energy_pj", "launches", "hbm_windows",
                   "hbm_edges")


def small_cfg(**kw):
    base = dict(f_pop=8, r_pop=8, u_pop=16, max_t2=8, cap_route_range=8,
                cap_route_update=32, cap_rangeq=128, cap_updq=2048,
                max_rounds=20000)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def g():
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    return CSRGraph.from_edges(n, src, dst, val)


@pytest.fixture(scope="module")
def pg(g):
    return alg.prepare(g, T=4)


def root_of(g):
    return int(np.argmax(g.ptr[1:] - g.ptr[:-1]))


# --------------------------------------------------------------------------
# The registry: per-space allocation rules.
# --------------------------------------------------------------------------

def test_registry_spaces_exist():
    assert get_space("vmem").capacity_bytes < get_space("hbm").capacity_bytes
    assert get_space("hbm").streamed and not get_space("vmem").streamed


def test_unknown_space_raises():
    with pytest.raises(ValueError, match="nosuch"):
        get_space("nosuch")


def test_hbm_cannot_hold_queues():
    # HBM holds only streamed edge shards — a task queue is word-random
    with pytest.raises(ValueError, match=r"'queue\[update\]'.*'hbm'"):
        check_alloc("hbm", "queue", "queue[update]")


def test_host_tier_not_yet_allocatable():
    with pytest.raises(ValueError, match="not yet allocatable"):
        check_alloc("host", "edge", "edge-shard[bfs]")


def test_queue_make_rejects_hbm():
    from repro.core.queues import queue_make
    with pytest.raises(ValueError, match="queue"):
        queue_make(16, 3, space="hbm", label="queue[range]")


def test_resolve_window_rules():
    gran = get_space("hbm").window
    # auto: next pow2 >= max_t2, floored at the HBM transfer granularity
    assert resolve_window(0, 8) == gran
    assert resolve_window(0, 200) == 256
    # explicit: anything >= max_t2 is honored, even below the granularity
    assert resolve_window(8, 8) == 8
    assert resolve_window(640, 8) == 640
    # explicit below max_t2 breaks the double-buffer invariant
    with pytest.raises(ValueError, match="hbm_window=4 < max_t2=8"):
        resolve_window(4, 8)


# --------------------------------------------------------------------------
# The config-time budget validator.
# --------------------------------------------------------------------------

def test_check_budgets_error_names_buffer_and_space():
    decls = [("queue[update]", "vmem", 9000), ("vertex-state", "vmem", 500)]
    with pytest.raises(ValueError) as ei:
        check_budgets("bfs", decls, vmem_limit_bytes=4096)
    msg = str(ei.value)
    assert "program 'bfs'" in msg
    assert "memory space 'vmem' over budget" in msg
    assert "9500 B > 4096 B" in msg
    assert "'queue[update]' (9000 B in 'vmem')" in msg  # the one to move
    assert "edge_space='hbm'" in msg  # remediation hint


def test_space_budget_override():
    assert space_budget("vmem") == get_space("vmem").capacity_bytes
    assert space_budget("vmem", override_bytes=4096) == 4096


def test_engine_rejects_over_budget_config(pg, g):
    cfg = small_cfg(vmem_limit_bytes=1024)
    with pytest.raises(ValueError, match="over budget"):
        alg.bfs(pg, root_of(g), cfg)


def test_triangles_pins_edge_shard_to_vmem(g):
    gs = alg.symmetrize(g)
    pgt = alg.prepare_triangles(gs, 4)
    with pytest.raises(ValueError, match="pins its edge shard to 'vmem'"):
        alg.triangles(pgt, small_cfg(edge_space="hbm"))
    # vmem (the pin) still runs
    res = alg.triangles(pgt, small_cfg())
    assert (res.values == ref.triangles_ref(gs, key=pgt.place)).all()


def test_resolve_edge_space_validates(pg):
    prog = as_program(alg.BFS)
    assert resolve_edge_space(prog, small_cfg()) == "vmem"
    assert resolve_edge_space(prog, small_cfg(edge_space="hbm")) == "hbm"
    with pytest.raises(ValueError, match="not yet allocatable"):
        resolve_edge_space(prog, small_cfg(edge_space="host"))


# --------------------------------------------------------------------------
# segment_stream == segment_gather (the kernel-level value contract).
# --------------------------------------------------------------------------

def _random_segments(rng, e_chunk, max_t2, R, ragged_last=False):
    """Range messages as range_split emits them: each segment <= max_t2
    edges, contiguous, not crossing the chunk border."""
    length = rng.integers(1, max_t2 + 1, size=R).astype(np.int32)
    local0 = (rng.integers(0, e_chunk, size=R) % (e_chunk - length)) \
        .astype(np.int32)
    if ragged_last:  # a segment ending exactly at the chunk border
        length[-1] = max_t2
        local0[-1] = e_chunk - max_t2
    rv = rng.random(R) < 0.8
    rv[0] = True
    return local0, (local0 + length), np.asarray(rv)


@pytest.mark.parametrize("window_mult,ragged", [(1, False), (1, True),
                                                (2, False), (16, True)])
def test_segment_stream_matches_gather(window_mult, ragged):
    rng = np.random.default_rng(7)
    e_chunk, max_t2, R = 256, 8, 24
    window = max_t2 * window_mult  # window == max_t2 is the tight corner
    edge_dst = rng.integers(-1, 64, size=e_chunk).astype(np.int32)
    edge_val = rng.random(e_chunk).astype(np.float32)
    start, stop, rv = _random_segments(rng, e_chunk, max_t2, R,
                                       ragged_last=ragged)
    nb_g, w_g, jv_g = segment_gather(edge_dst, edge_val, start, stop, rv,
                                     max_t2)
    nb_s, w_s, jv_s = segment_stream(edge_dst, edge_val, start, stop, rv,
                                     max_t2, window)
    np.testing.assert_array_equal(np.asarray(jv_g), np.asarray(jv_s))
    jv = np.asarray(jv_g)
    # valid lanes are bit-identical; invalid lanes are don't-cares
    np.testing.assert_array_equal(np.asarray(nb_g)[jv], np.asarray(nb_s)[jv])
    np.testing.assert_array_equal(np.asarray(w_g)[jv], np.asarray(w_s)[jv])


def test_segment_stream_empty_frontier():
    e_chunk, max_t2, window = 64, 8, 8
    edge_dst = np.arange(e_chunk, dtype=np.int32)
    edge_val = np.ones(e_chunk, dtype=np.float32)
    z = np.zeros(4, dtype=np.int32)
    rv = np.zeros(4, dtype=bool)
    nb, w, jv = segment_stream(edge_dst, edge_val, z, z, rv, max_t2, window)
    assert not np.asarray(jv).any()


# --------------------------------------------------------------------------
# End-to-end space equivalence: vmem vs hbm, xla and pallas.
# --------------------------------------------------------------------------

def assert_space_equivalent(r_hbm, r_vmem, label):
    np.testing.assert_array_equal(r_hbm.values, r_vmem.values,
                                  err_msg=f"values ({label})")
    for f in r_vmem.stats._fields:
        if f in SPACE_DEPENDENT:
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r_hbm.stats, f)),
            np.asarray(getattr(r_vmem.stats, f)),
            err_msg=f"stats.{f} ({label})")


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("app", ["bfs", "spmv"])
def test_hbm_run_bit_identical_to_vmem(pg, g, app, backend):
    x = np.linspace(0.5, 1.5, g.num_vertices).astype(np.float32)
    run = (lambda cfg: alg.bfs(pg, root_of(g), cfg)) if app == "bfs" \
        else (lambda cfg: alg.spmv(pg, x, cfg))
    r_vmem = run(small_cfg(backend=backend))
    r_hbm = run(small_cfg(backend=backend, edge_space="hbm"))
    assert_space_equivalent(r_hbm, r_vmem, f"{app}/{backend}")
    window = resolve_window(0, small_cfg().max_t2)
    assert int(r_hbm.stats.hbm_windows) > 0
    assert int(r_hbm.stats.hbm_edges) == \
        int(r_hbm.stats.hbm_windows) * window
    assert int(r_vmem.stats.hbm_windows) == 0  # vmem runs never stream
    assert int(r_vmem.stats.hbm_edges) == 0
    # the streamed words are priced: same work, strictly costlier
    assert float(r_hbm.stats.cycles) > float(r_vmem.stats.cycles)
    assert float(r_hbm.stats.energy_pj) > float(r_vmem.stats.energy_pj)


def test_hbm_pallas_matches_hbm_xla_exactly(pg, g):
    """Same space, different backend: everything matches, pricing
    included (the backend contract of fig11 extended to the streamed
    path; launches excluded by design)."""
    root = root_of(g)
    rx = alg.bfs(pg, root, small_cfg(edge_space="hbm"))
    for fuse in (True, False):
        rp = alg.bfs(pg, root, small_cfg(edge_space="hbm",
                                         backend="pallas",
                                         pallas_fuse=fuse))
        np.testing.assert_array_equal(rp.values, rx.values)
        for f in rx.stats._fields:
            if f == "launches":
                continue
            np.testing.assert_array_equal(
                np.asarray(getattr(rp.stats, f)),
                np.asarray(getattr(rx.stats, f)),
                err_msg=f"stats.{f} (pallas_fuse={fuse})")
        assert int(rp.stats.launches) > 0


def test_explicit_window_changes_pricing_not_values(pg, g):
    root = root_of(g)
    tight = alg.bfs(pg, root, small_cfg(edge_space="hbm", hbm_window=8))
    auto = alg.bfs(pg, root, small_cfg(edge_space="hbm"))
    assert_space_equivalent(tight, auto, "window=8 vs auto")
    assert int(tight.stats.hbm_windows) == int(auto.stats.hbm_windows)
    # same window COUNT, fewer words per window -> cheaper streaming
    assert int(tight.stats.hbm_edges) < int(auto.stats.hbm_edges)
    assert float(tight.stats.energy_pj) < float(auto.stats.energy_pj)


def test_hbm_energy_reconciles_and_prices_e_hbm(pg, g):
    from repro.noc import make_network
    from repro.perf import energy_from_totals
    cfg = small_cfg(edge_space="hbm")
    s = alg.bfs(pg, root_of(g), cfg).stats
    assert int(s.hbm_edges) > 0
    net = make_network(cfg, pg.T)
    want = energy_from_totals(s, cfg.perf, net, pg.T)
    assert float(s.energy_pj) == pytest.approx(want, rel=1e-4)
    # the HBM term is exactly hbm_edges * e_hbm: repricing e_hbm to zero
    # must remove it and nothing else
    import dataclasses
    zeroed = dataclasses.replace(cfg.perf, e_hbm=0.0)
    assert want - energy_from_totals(s, zeroed, net, pg.T) == \
        pytest.approx(int(s.hbm_edges) * cfg.perf.e_hbm, rel=1e-6)


def test_beyond_vmem_acceptance(pg, g):
    """The PR's acceptance property: a budget the resident shard cannot
    fit rejects the all-VMEM layout at config time, while the HBM layout
    runs the same graph end to end, bit-identical to the unconstrained
    vmem run, with nonzero per-space counters."""
    import dataclasses
    root = root_of(g)
    base = small_cfg()
    hbm = small_cfg(edge_space="hbm", hbm_window=base.max_t2)
    prog = as_program(alg.BFS)

    def vmem_bytes(c):
        return sum(b for _, sp, b in
                   prog.tile_decls(c, pg.T, pg.e_chunk, pg.v_chunk)
                   if sp == "vmem")

    limit = (vmem_bytes(hbm) + vmem_bytes(base)) // 2
    with pytest.raises(ValueError, match="over budget"):
        alg.bfs(pg, root, dataclasses.replace(base,
                                              vmem_limit_bytes=limit))
    r_vmem = alg.bfs(pg, root, base)
    r_hbm = alg.bfs(pg, root,
                    dataclasses.replace(hbm, vmem_limit_bytes=limit))
    assert_space_equivalent(r_hbm, r_vmem, "beyond-vmem")
    assert int(r_hbm.stats.hbm_edges) > 0
    np.testing.assert_array_equal(r_hbm.values, ref.bfs_ref(g, root))


# --------------------------------------------------------------------------
# SPMD: the HBM stream under real shard_map (subprocess: multi-device CPU
# needs XLA_FLAGS before jax initializes).
# --------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import algorithms as alg
    from repro.core import reference as ref
    from repro.core.engine import EngineConfig
    from repro.core.graph import CSRGraph, rmat_edges

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("x",))
    n, src, dst, val = rmat_edges(7, edge_factor=5, seed=3)
    g = CSRGraph.from_edges(n, src, dst, val)
    pg = alg.prepare(g, T=8)
    cfg = EngineConfig(f_pop=8, r_pop=8, u_pop=16, max_t2=8,
                       cap_route_range=8, cap_route_update=32,
                       cap_rangeq=128, cap_updq=4096, max_rounds=5000,
                       edge_space="hbm")
    root = int(np.argmax(g.ptr[1:] - g.ptr[:-1]))
    r_spmd = alg.bfs(pg, root, cfg, mesh=mesh)
    r_local = alg.bfs(pg, root, cfg)
    np.testing.assert_array_equal(r_spmd.values, r_local.values)
    np.testing.assert_array_equal(r_spmd.values, ref.bfs_ref(g, root))
    assert int(r_spmd.stats.hbm_windows) == int(r_local.stats.hbm_windows)
    assert int(r_spmd.stats.hbm_edges) == int(r_local.stats.hbm_edges)
    assert int(r_spmd.stats.hbm_edges) > 0
    assert float(r_spmd.stats.cycles) == float(r_local.stats.cycles)
    assert float(r_spmd.stats.energy_pj) == float(r_local.stats.energy_pj)
    assert int(r_spmd.stats.drops) == 0
    print("SPMD-HBM-OK")
""")


@pytest.mark.slow
def test_spmd_hbm_stream_matches_local():
    env = dict(os.environ)
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr
    assert "SPMD-HBM-OK" in out.stdout
